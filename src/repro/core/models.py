"""Decision-forest Model implementations + shared training-preparation.

``DecisionForestModel`` holds a Forest SoA, the training DataSpec and feature
list, and routes ``predict`` through a (lossily) compiled inference engine
(§3.7) — see repro/core/engines.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.obs import trace
from repro.core.api import Model, Task, YdfError
from repro.core.binning import BinnedFeatures, bin_features
from repro.core.dataspec import (
    DataSpec,
    Semantic,
    VerticalDataset,
    check_classification_label,
    dataset_from_raw,
    encode_dataset,
    infer_dataspec,
)
from repro.core.evaluation import Evaluation
from repro.core.tree import Forest, aggregate_gbt, aggregate_rf


# ---------------------------------------------------------------- prep

@dataclass
class TrainData:
    ds: VerticalDataset
    features: list[str]
    binned: BinnedFeatures
    X_raw: np.ndarray          # (N, F) float32: raw numerical values / cat codes
    y: np.ndarray              # class idx (0-based) or float target
    w: np.ndarray              # example weights
    n_classes: int
    classes: list[str] | None
    num_lo: np.ndarray         # per numerical feature: min (oblique min-max)
    num_hi: np.ndarray
    # task side-channels (DESIGN.md §12): never input features
    groups: np.ndarray | None = None     # (N,) int64 ranking group ids
    treatment: np.ndarray | None = None  # (N,) int64 uplift arm (0=control)


def _as_vertical(dataset, spec: DataSpec | None = None) -> VerticalDataset:
    if isinstance(dataset, VerticalDataset):
        return dataset
    if spec is not None:
        return encode_dataset(dataset, spec)
    return dataset_from_raw(dataset)


def raw_matrix(ds: VerticalDataset, features: list[str]) -> np.ndarray:
    """Raw-value matrix with GLOBAL imputation from the dataspec (mean /
    most-frequent==code 1, since dictionaries are frequency-ordered)."""
    N = ds.n_rows
    X = np.zeros((N, len(features)), np.float32)
    for j, name in enumerate(features):
        col = ds.spec[name]
        if col.semantic == Semantic.NUMERICAL:
            v = ds.numerical[name].astype(np.float32).copy()
            v[np.isnan(v)] = np.float32(col.mean)
            X[:, j] = v
        else:
            v = ds.categorical[name].astype(np.float32).copy()
            fill = 1.0 if col.vocab_size > 1 else 0.0
            v[v < 0] = fill
            X[:, j] = v
    return X


def prepare_train_data(learner, dataset, *, features: list[str] | None = None,
                       max_bins: int = 255) -> TrainData:
    ds = _as_vertical(dataset)
    label = learner.label
    if label not in ds.spec.columns:
        raise YdfError(
            f'Label column "{label}" not found in the training dataset. '
            f"Available columns: {sorted(ds.spec.columns)}.")
    # task side-channel columns (ranking group / uplift treatment) are
    # extracted here and NEVER become input features — a model that splits
    # on its own query id or treatment assignment is leakage, not learning
    exclude: list[str] = []
    groups = treatment = None
    if learner.task == Task.RANKING:
        gcol = getattr(learner.hparams, "ranking_group", "group")
        if gcol not in ds.spec.columns:
            raise YdfError(
                f'Ranking training requires the group/query column "{gcol}" '
                f"in the dataset. Available columns: {sorted(ds.spec.columns)}. "
                "Solution: add the column, or point ranking_group= at it.")
        exclude.append(gcol)
        groups = np.unique(np.asarray(ds.column(gcol)).astype(str),
                           return_inverse=True)[1].astype(np.int64)
    elif learner.task == Task.UPLIFT:
        tcol = getattr(learner.hparams, "treatment", "treatment")
        if tcol not in ds.spec.columns:
            raise YdfError(
                f'Uplift training requires the treatment column "{tcol}" in '
                f"the dataset. Available columns: {sorted(ds.spec.columns)}. "
                "Solution: add the column, or point treatment= at it.")
        exclude.append(tcol)
        vals, t = np.unique(np.asarray(ds.column(tcol)).astype(str),
                            return_inverse=True)
        if len(vals) != 2:
            raise YdfError(
                f'Uplift treatment column "{tcol}" must have exactly two '
                f"distinct values (control, treated); found {len(vals)}: "
                f"{list(vals[:5])}.")
        treatment = t.astype(np.int64)
    feats = ds.spec.feature_names(label, features, exclude=exclude)
    col = ds.spec[label]
    if learner.task == Task.CLASSIFICATION:
        check_classification_label(col, learner.task)
        classes = col.vocab[1:]
        n_classes = len(classes)
        if n_classes < 2:
            raise YdfError(
                f"{learner.task.value} training (task=CLASSIFICATION) requires "
                f'a label with >= 2 classes, however {n_classes} classe(s) were '
                f'found in the label column "{label}": {classes}. Possible '
                "solutions: (1) use a training dataset with more label "
                "diversity, or (2) use task=REGRESSION for numerical targets.")
        y_enc = ds.categorical[label]
        if (y_enc <= 0).any():
            raise YdfError(
                f'Label column "{label}" has missing/out-of-dictionary values '
                "in the training set; every training example must be labeled.")
        y = (y_enc - 1).astype(np.int32)
    else:
        task_name = learner.task.value.capitalize()
        if col.semantic == Semantic.BOOLEAN and learner.task == Task.UPLIFT:
            # binary outcomes are the normal uplift case; codes are 0/1
            y = ds.column(label).astype(np.float64)
            if (y < 0).any():
                raise YdfError(
                    f'{task_name} label "{label}" contains missing values.')
        elif col.semantic != Semantic.NUMERICAL:
            raise YdfError(
                f'{task_name} training requires a NUMERICAL label, but "{label}" '
                f"is {col.semantic.value}. Solution: use task=CLASSIFICATION.")
        else:
            y = ds.numerical[label].astype(np.float64)
            if np.isnan(y).any():
                raise YdfError(
                    f'{task_name} label "{label}" contains missing values.')
        classes, n_classes = None, 0
    with trace.span("grower/binning", rows=ds.n_rows, features=len(feats)):
        binned = bin_features(ds, feats, max_bins=max_bins)
    X_raw = raw_matrix(ds, feats)
    num_cols = np.where(~binned.is_cat)[0]
    if len(num_cols) and ds.n_rows:
        num_lo = X_raw[:, num_cols].min(0).astype(np.float32)
        num_hi = X_raw[:, num_cols].max(0).astype(np.float32)
    else:
        num_lo = np.zeros(len(num_cols), np.float32)
        num_hi = np.ones(len(num_cols), np.float32)
    w = np.ones(ds.n_rows, np.float64)
    return TrainData(ds=ds, features=feats, binned=binned, X_raw=X_raw, y=y,
                     w=w, n_classes=n_classes, classes=classes,
                     num_lo=num_lo, num_hi=num_hi,
                     groups=groups, treatment=treatment)


def extract_validation(n: int, ratio: float, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic train/valid index split (paper §3.3: learners extract
    their own validation set when none is provided)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_valid = int(round(n * ratio))
    return np.sort(perm[n_valid:]), np.sort(perm[:n_valid])


# ---------------------------------------------------------------- model

class DecisionForestModel(Model):
    def __init__(self, *, forest: Forest, spec: DataSpec, features: list[str],
                 label: str, task: Task, classes: list[str] | None,
                 self_evaluation: Evaluation | None = None):
        self.forest = forest
        self.spec = spec
        self.features = features
        self.label = label
        self.task = task
        self.classes = classes
        self.self_evaluation = self_evaluation
        self._engine = None
        self._predictor = None

    # -------- engines + compiled predictor (§3.7; DESIGN.md §5.1)
    def compile(self, engine: str | None = None):
        """(Re)compile the serving stack: encode tables + engine closure +
        output head. Returns the selected Engine (back-compat); the full
        CompiledPredictor is available via ``predictor()``."""
        from repro.core.engines import compile_predictor
        self._predictor = compile_predictor(self, engine)
        self._engine = self._predictor.engine
        return self._engine

    def predictor(self, engine: str | None = None):
        """The cached CompiledPredictor; compiled on first use and reused by
        every subsequent ``predict`` call (§5.1 lifecycle)."""
        if self._predictor is None or \
                (engine is not None and self._predictor.name != engine):
            self.compile(engine)
        return self._predictor

    def __getstate__(self):
        # engines/predictors are runtime artifacts (closures over device
        # buffers, encode tables) and are recompiled on load — exactly the
        # Model/engine split of §3.7
        state = dict(self.__dict__)
        state["_engine"] = None
        state["_predictor"] = None
        return state

    def _scores(self, dataset) -> np.ndarray:
        """(N, T, out_dim) per-tree outputs via the compiled predictor."""
        p = self.predictor()
        return np.asarray(p.per_tree(p.encode(dataset)))

    def _finalize(self, per_tree: np.ndarray) -> np.ndarray:
        """Aggregation + activation head applied to per-tree outputs."""
        return self._compile_finalize()(per_tree)

    def _compile_finalize(self):
        """Self-contained finalize closure for the CompiledPredictor: it
        must capture the fields it needs, NOT ``self`` — a bound method
        would cycle Model <-> predictor and delay the device-buffer release
        that the forest cache's weakref finalizer provides."""
        raise NotImplementedError

    def predict(self, dataset) -> np.ndarray:
        return self.predictor().predict(dataset)

    # -------- typed tree API (DESIGN.md §7)
    def inspect(self):
        """A ``py_tree.ModelInspector``: iterate trees as typed nodes,
        per-tree depth/leaf stats, plot_tree-style ASCII rendering."""
        from repro.core.py_tree import ModelInspector
        return ModelInspector(self)

    def summary(self, verbose: int | bool = False) -> str:
        c = self.forest.node_counts()
        lines = [f"Type: {type(self).__name__}",
                 f"Task: {self.task.value}", f'Label: "{self.label}"',
                 f"Input Features ({len(self.features)}): {self.features}",
                 f"Number of trees: {c['n_trees']}",
                 f"Total number of nodes: {c['total_nodes']}",
                 f"Max depth: {self.forest.depth}"]
        vi = self.variable_importances()
        for kind, table in vi.items():
            top = sorted(table.items(), key=lambda kv: -kv[1])[:5]
            lines.append(f"Variable Importance {kind}: "
                         + ", ".join(f'"{k}" {v:g}' for k, v in top))
        if self.self_evaluation is not None:
            lines.append("Self-evaluation: "
                         + f"{self.self_evaluation.source}: "
                         + ", ".join(f"{k}={v:.4g}" for k, v in
                                     self.self_evaluation.metrics.items()
                                     if isinstance(v, float)))
        logs = getattr(self, "training_logs", None)
        if isinstance(logs, dict):
            from repro.obs import summarize_training_logs
            lines.extend(summarize_training_logs(logs))
            oob = logs.get("oob")
            if oob:
                lines.append(
                    f"Out-of-bag coverage: {oob['coverage']:.1%} of training "
                    f"examples "
                    f"({oob['mean_trees_per_example']:.1f} trees/example)")
        if verbose:
            insp = self.inspect()
            st = insp.stats_summary()
            lines.append(
                f"Tree depths: min={st['depth_min']} "
                f"mean={st['depth_mean']:.1f} max={st['depth_max']}; "
                f"leaves/tree mean={st['leaves_mean']:.1f} "
                f"(total {st['leaves_total']})")
            max_depth = 4 if verbose is True else int(verbose)
            lines.append(f"Tree #0 (first {max_depth} levels):")
            lines.append(insp.plot_tree(0, max_depth=max_depth))
        return "\n".join(lines)

    def variable_importances(self) -> dict[str, dict[str, float]]:
        return self.forest.variable_importances()


class GradientBoostedTreesModel(DecisionForestModel):
    def __init__(self, *, loss, **kw):
        super().__init__(**kw)
        self.loss = loss

    def _compile_finalize(self):
        return _GbtFinalize(self.loss, self.forest)

    def predict_scores(self, dataset) -> np.ndarray:
        return aggregate_gbt(self._scores(dataset), self.forest)


class RandomForestModel(DecisionForestModel):
    def __init__(self, *, winner_take_all: bool = True, **kw):
        super().__init__(**kw)
        self.winner_take_all = winner_take_all

    def _compile_finalize(self):
        return _RfFinalize(self.winner_take_all and
                           self.task == Task.CLASSIFICATION,
                           self.task == Task.REGRESSION)


class CartModel(RandomForestModel):
    pass


class UpliftModel(DecisionForestModel):
    """Honest uplift forest (DESIGN.md §12.2): every leaf stores the local
    treatment effect p_t - p_c; predict() averages leaves over trees, so the
    output is the per-example estimated uplift (positive = treat)."""

    def __init__(self, *, treatment_col: str = "treatment", **kw):
        super().__init__(**kw)
        self.treatment_col = treatment_col

    def _compile_finalize(self):
        return _RfFinalize(False, True)   # mean over trees, scalar output


class IsolationForestModel(DecisionForestModel):
    """Isolation forest (DESIGN.md §12.3): leaves store the path length
    depth + c(n); predict() maps the mean path length h through the anomaly
    score 2^(-h / c(psi)) — near 1 for anomalies, well below 1 for inliers."""

    def __init__(self, *, c_psi: float, **kw):
        super().__init__(**kw)
        self.c_psi = c_psi

    def _compile_finalize(self):
        return _IsolationFinalize(self.c_psi)


# finalize heads are module-level callable classes, not lambdas, so a
# CompiledPredictor pickles whole (engines.py §10.4); they capture the
# fields they need, NOT the model — see _compile_finalize's cycle note

@dataclass
class _GbtFinalize:
    loss: object
    forest: Forest

    def __call__(self, per_tree: np.ndarray) -> np.ndarray:
        return self.loss.activation(aggregate_gbt(per_tree, self.forest))


@dataclass
class _RfFinalize:
    wta: bool
    regression: bool

    def __call__(self, per_tree: np.ndarray) -> np.ndarray:
        out = aggregate_rf(per_tree, self.wta)
        return out[:, 0] if self.regression else out


@dataclass
class _IsolationFinalize:
    c_psi: float

    def __call__(self, per_tree: np.ndarray) -> np.ndarray:
        # per_tree: (N, T, 1) path lengths; Liu et al. 2008 eq. 2
        h = np.asarray(per_tree)[..., 0].mean(axis=1)
        return np.power(2.0, -h / max(self.c_psi, 1e-12))
