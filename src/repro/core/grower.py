"""Tree growth engine shared by GBT / RF / CART learners.

Two strategies (paper §3.11 templates):
  * LOCAL              — divide-and-conquer, level-wise: every frontier node of
                         the current depth is split in one histogram pass
                         (one scatter over all active examples).
  * BEST_FIRST_GLOBAL  — leaf-wise (Shi 2007): repeatedly split the leaf with
                         the best gain until the node budget is exhausted;
                         child histograms use the parent-minus-sibling
                         subtraction trick.

The grower owns node allocation in the Forest SoA and the per-example
``node_of`` routing; leaf values come from a caller-provided ``leaf_fn`` over
aggregated node stats.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.core.binning import BinnedFeatures
from repro.core.splitters import (
    Split,
    SplitterParams,
    apply_split,
    best_splits,
    build_histogram,
    oblique_splits,
)
from repro.core.tree import MASK_WORDS, Forest


@dataclass
class GrowthParams:
    max_depth: int = 6
    max_nodes: int = 2048           # total node budget per tree
    growing_strategy: str = "LOCAL"  # LOCAL | BEST_FIRST_GLOBAL
    splitter: SplitterParams = None  # type: ignore


def _set_split(forest: Forest, t: int, node: int, split: Split,
               binned: BinnedFeatures) -> None:
    if split.obl_features is not None:
        forest.feature[t, node] = -2
        k = min(len(split.obl_features), forest.obl_weights.shape[-1])
        forest.obl_features[t, node, :k] = split.obl_features[:k]
        forest.obl_weights[t, node, :k] = split.obl_weights[:k]
        forest.threshold[t, node] = split.threshold
        return
    forest.feature[t, node] = split.feature
    if split.cat_right is not None:
        for c in split.cat_right:
            forest.cat_mask[t, node, c // 32] |= np.uint32(1) << np.uint32(c % 32)
    else:
        forest.threshold[t, node] = split.threshold
        forest.split_bin[t, node] = split.split_bin


def _feature_sample_mask(n_nodes: int, F: int, ratio: float,
                         rng: np.random.Generator) -> np.ndarray | None:
    if ratio >= 1.0:
        return None
    k = max(1, int(round(ratio * F)))
    mask = np.zeros((n_nodes, F), bool)
    for i in range(n_nodes):
        mask[i, rng.choice(F, size=k, replace=False)] = True
    return mask


def grow_tree(forest: Forest, t: int, binned: BinnedFeatures, X_raw: np.ndarray,
              stats: np.ndarray, active: np.ndarray,
              leaf_fn: Callable[[np.ndarray], np.ndarray],
              params: GrowthParams, rng: np.random.Generator,
              num_lo: np.ndarray | None = None,
              num_hi: np.ndarray | None = None) -> np.ndarray:
    """Grow tree `t` in place. `active`: (N,) bool/float example weights > 0
    mask; `stats` must already include bagging weights. Returns the final
    ``node_of`` array ((N,) int32, -1 for inactive examples) so boosting can
    read leaf assignments without re-traversal."""
    sp = params.splitter
    N = binned.codes.shape[0]
    node_of = np.where(active, 0, -1).astype(np.int32)
    root_stats = stats[active].sum(0)
    forest.leaf_value[t, 0] = leaf_fn(root_stats)
    forest.n_nodes[t] = 1
    if params.growing_strategy == "BEST_FIRST_GLOBAL":
        depth = _grow_best_first(forest, t, binned, X_raw, stats, node_of,
                                 params, rng, leaf_fn, num_lo, num_hi)
    else:
        depth = _grow_level_wise(forest, t, binned, X_raw, stats, node_of,
                                 params, rng, leaf_fn, num_lo, num_hi)
    forest.depth = max(forest.depth, depth)
    return node_of


def _node_best_split(hist_slice, binned, sp, rng, X_raw, stats, node_of_c,
                     n_slots, num_lo, num_hi, mask=None) -> list[Split]:
    splits = best_splits(hist_slice, binned, sp, rng, feature_mask=mask)
    if sp.oblique and num_lo is not None:
        Fn = (~binned.is_cat).sum()
        if Fn:
            num_cols = np.where(~binned.is_cat)[0]
            obl = oblique_splits(X_raw[:, num_cols], num_lo, num_hi, stats,
                                 node_of_c, n_slots, sp, rng)
            for i in range(n_slots):
                if obl[i].gain > splits[i].gain:
                    o = obl[i]
                    # remap feature indices back to full-matrix columns
                    o.obl_features = num_cols[o.obl_features].astype(np.int32)
                    splits[i] = o
    return splits


def _grow_level_wise(forest, t, binned, X_raw, stats, node_of, params, rng,
                     leaf_fn, num_lo, num_hi) -> int:
    sp = params.splitter
    F = binned.n_features
    frontier = [0]
    depth = 0
    for level in range(params.max_depth):
        if not frontier:
            break
        slot_of_node = {n: i for i, n in enumerate(frontier)}
        slot = np.full(forest.max_nodes, -1, np.int32)
        for n, i in slot_of_node.items():
            slot[n] = i
        node_of_c = np.where(node_of >= 0, slot[np.maximum(node_of, 0)], -1)
        hist = build_histogram(binned.codes, stats, node_of_c, len(frontier))
        mask = _feature_sample_mask(len(frontier), F, sp.num_candidate_ratio, rng)
        splits = _node_best_split(hist, binned, sp, rng, X_raw, stats,
                                  node_of_c, len(frontier), num_lo, num_hi, mask)
        new_frontier = []
        for i, node in enumerate(frontier):
            s = splits[i]
            if not s.valid or forest.n_nodes[t] + 2 > params.max_nodes:
                continue
            left = int(forest.n_nodes[t])
            forest.n_nodes[t] += 2
            _set_split(forest, t, node, s, binned)
            forest.left_child[t, node] = left
            idx = np.where(node_of == node)[0]
            go = apply_split(s, binned, X_raw, idx)
            node_of[idx] = np.where(go, left + 1, left)
            for child, sel in ((left, ~go), (left + 1, go)):
                cs = stats[idx[sel]].sum(0)
                forest.leaf_value[t, child] = leaf_fn(cs)
                new_frontier.append(child)
            depth = level + 1
        frontier = new_frontier
    return depth


def _grow_best_first(forest, t, binned, X_raw, stats, node_of, params, rng,
                     leaf_fn, num_lo, num_hi) -> int:
    """Leaf-wise growth. Heap holds (-gain, node, depth, Split)."""
    sp = params.splitter
    F = binned.n_features

    def eval_node(node: int) -> Split:
        mask01 = (node_of == node).astype(np.int32)
        node_of_c = np.where(mask01 > 0, 0, -1).astype(np.int32)
        hist = build_histogram(binned.codes, stats, node_of_c, 1)
        m = _feature_sample_mask(1, F, sp.num_candidate_ratio, rng)
        return _node_best_split(hist, binned, sp, rng, X_raw, stats, node_of_c,
                                1, num_lo, num_hi, m)[0]

    heap: list = []
    counter = 0
    s0 = eval_node(0)
    if s0.valid:
        heapq.heappush(heap, (-s0.gain, counter, 0, 0, s0))
        counter += 1
    depth = 0
    while heap and forest.n_nodes[t] + 2 <= params.max_nodes:
        ngain, _, node, d, s = heapq.heappop(heap)
        left = int(forest.n_nodes[t])
        forest.n_nodes[t] += 2
        _set_split(forest, t, node, s, binned)
        forest.left_child[t, node] = left
        idx = np.where(node_of == node)[0]
        go = apply_split(s, binned, X_raw, idx)
        node_of[idx] = np.where(go, left + 1, left)
        depth = max(depth, d + 1)
        for child in (left, left + 1):
            cidx = np.where(node_of == child)[0]
            forest.leaf_value[t, child] = leaf_fn(stats[cidx].sum(0))
            if d + 1 < params.max_depth and len(cidx) >= 2 * sp.min_examples:
                cs = eval_node(child)
                if cs.valid:
                    heapq.heappush(heap, (-cs.gain, counter, child, d + 1, cs))
                    counter += 1
    return depth
