"""Tree growth engine shared by GBT / RF / CART learners.

Two strategies (paper §3.11 templates):
  * LOCAL              — divide-and-conquer, level-wise: every frontier node of
                         the current depth is split in one histogram pass
                         (one scatter over all active examples).
  * BEST_FIRST_GLOBAL  — leaf-wise (Shi 2007): repeatedly split the leaf with
                         the best gain until the node budget is exhausted;
                         child histograms use the parent-minus-sibling
                         subtraction trick.

Three engines (DESIGN.md §4, §6):
  * "batched" — the host fast path. Level-wise: one vectorized ``apply_split``
    pass routes every frontier example and one flattened bincount aggregates
    all child leaf stats. Best-first: per-node example index lists ride the
    heap, only the smaller child's histogram is built and the sibling is
    derived as ``parent - child``, making node evaluation O(smaller child)
    instead of O(N). Histograms go through a pluggable backend
    (hist_backend.py: numpy bincount or the one-hot-MXU Pallas kernel),
    selected by ``GrowthParams.histogram_backend``.
  * "oracle"  — the seed-equivalent simple module (paper §2.3: the simple
    implementation is the ground truth): per-node partition loops and full-N
    histogram rebuilds, host numpy only. With the numpy backend the batched
    engine produces bit-identical trees at equal seeds (tested).
  * "device"  — the device-resident jitted level loop (grower_device.py,
    DESIGN.md §6): fused hist+gain kernel, padded power-of-two frontier, one
    host sync per level (a single int32) and one forest fetch per tree block.

Independent trees (Random Forest) can also grow as lockstep BLOCKS through
``grow_trees``: with keyed per-node feature sampling (sampling.py) the growth
schedule is semantics-free, so K trees advance one level per pass — the host
lockstep path gathers only each node's sampled feature columns into one
block-wide bincount (``best_splits_gathered``), which is what makes sqrt(F)
Random Forest growth pay (DESIGN.md §6.3).

The grower owns node allocation in the Forest SoA and the per-example
``node_of`` routing; leaf values come from a caller-provided ``leaf_fn`` over
aggregated node stats.
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.obs import trace
from repro.core.api import YdfError
from repro.core.binning import BinnedFeatures
from repro.core.hist_backend import (
    HistogramBackend,
    _unique_stat_columns,
    resolve_backend,
)
from repro.core.sampling import keyed_feature_select, sample_size
from repro.core.splitters import (
    Split,
    SplitterParams,
    apply_split,
    best_splits,
    best_splits_gathered,
    build_histogram,
    oblique_splits,
)
from repro.core.tree import MASK_WORDS, Forest


@dataclass
class GrowthParams:
    max_depth: int = 6
    max_nodes: int = 2048           # total node budget per tree
    growing_strategy: str = "LOCAL"  # LOCAL | BEST_FIRST_GLOBAL
    splitter: SplitterParams = field(default_factory=SplitterParams)
    engine: str = "batched"          # batched | oracle | device (DESIGN.md §6)
    histogram_backend: str = "auto"  # auto | numpy | pallas (batched engine)
    # per-node feature sampling policy: "stream" draws masks from the shared
    # rng (seed-faithful; couples draws to the growth schedule), "keyed"
    # hashes (sampling_key, tree, node) — sampling.py — so every engine and
    # execution order derives identical subsets (lockstep/device-safe).
    feature_sampling: str = "stream"     # stream | keyed
    sampling_key: int = 0
    device_impl: str = "auto"            # auto | jnp | pallas | interpret


def _set_split(forest: Forest, t: int, node: int, split: Split,
               binned: BinnedFeatures) -> None:
    if forest.split_gain is not None:
        # recorded for the SUM_SCORE structural importance (DESIGN.md §8);
        # never read back by training, so it cannot perturb growth
        forest.split_gain[t, node] = max(float(split.gain), 0.0)
    if split.obl_features is not None:
        forest.feature[t, node] = -2
        k = min(len(split.obl_features), forest.obl_weights.shape[-1])
        forest.obl_features[t, node, :k] = split.obl_features[:k]
        forest.obl_weights[t, node, :k] = split.obl_weights[:k]
        forest.threshold[t, node] = split.threshold
        return
    forest.feature[t, node] = split.feature
    if split.cat_right is not None:
        for c in split.cat_right:
            forest.cat_mask[t, node, c // 32] |= np.uint32(1) << np.uint32(c % 32)
    else:
        forest.threshold[t, node] = split.threshold
        forest.split_bin[t, node] = split.split_bin


def _feature_sample_mask(n_nodes: int, F: int, ratio: float,
                         rng: np.random.Generator) -> np.ndarray | None:
    if ratio >= 1.0:
        return None
    k = sample_size(ratio, F)
    mask = np.zeros((n_nodes, F), bool)
    for i in range(n_nodes):
        mask[i, rng.choice(F, size=k, replace=False)] = True
    return mask


def _candidate_mask(nodes, t: int, F: int, params: GrowthParams,
                    rng: np.random.Generator) -> np.ndarray | None:
    """Per-node candidate-feature mask for frontier ``nodes`` of tree ``t``
    under the active sampling policy (stream rng draws vs keyed hashes)."""
    sp = params.splitter
    if sp.num_candidate_ratio >= 1.0:
        return None
    if params.feature_sampling == "keyed":
        sel = keyed_feature_select(params.sampling_key, int(t),
                                   np.asarray(nodes, np.int64), F,
                                   sample_size(sp.num_candidate_ratio, F))
        mask = np.zeros((len(sel), F), bool)
        np.put_along_axis(mask, sel, True, axis=1)
        return mask
    return _feature_sample_mask(len(nodes), F, sp.num_candidate_ratio, rng)


def resolve_engine(params: GrowthParams, binned: BinnedFeatures | None = None,
                   oblique_active: bool = False) -> tuple[str, str | None]:
    """Map ``params.engine`` to the engine that will actually run, plus a
    fallback reason (None when the request is honored). The "device" engine
    supports the level-wise axis-aligned CART/ONE_HOT configurations; other
    configurations fall back to the host "batched" engine."""
    if params.engine not in ("batched", "oracle", "device"):
        raise YdfError(f"Unknown growth engine {params.engine!r}. "
                       "Expected one of: 'batched', 'oracle', 'device'.")
    if params.engine != "device":
        return params.engine, None
    from repro.core.grower_device import device_unsupported_reason
    reason = device_unsupported_reason(params, binned, oblique_active)
    return ("batched", reason) if reason else ("device", None)


def grow_tree(forest: Forest, t: int, binned: BinnedFeatures, X_raw: np.ndarray,
              stats: np.ndarray, active: np.ndarray,
              leaf_fn: Callable[[np.ndarray], np.ndarray],
              params: GrowthParams, rng: np.random.Generator,
              num_lo: np.ndarray | None = None,
              num_hi: np.ndarray | None = None) -> np.ndarray:
    """Grow tree `t` in place. `active`: (N,) bool/float example weights > 0
    mask; `stats` must already include bagging weights. Returns the final
    ``node_of`` array ((N,) int32, -1 for inactive examples) so boosting can
    read leaf assignments without re-traversal."""
    node_of = np.where(active, 0, -1).astype(np.int32)
    root_stats = stats[active].sum(0)
    forest.leaf_value[t, 0] = leaf_fn(root_stats)
    forest.n_nodes[t] = 1
    best_first = params.growing_strategy == "BEST_FIRST_GLOBAL"
    engine, _ = resolve_engine(params, binned,
                               params.splitter.oblique and num_lo is not None)
    if engine == "oracle":
        fn = _grow_best_first_oracle if best_first else _grow_level_wise_oracle
        depth = fn(forest, t, binned, X_raw, stats, node_of, params, rng,
                   leaf_fn, num_lo, num_hi)
    elif engine == "device":
        from repro.core.grower_device import grow_trees_device
        return grow_trees_device(forest, [t], binned, [stats], [active],
                                 leaf_fn, params)[0]
    else:
        backend = resolve_backend(params.histogram_backend)
        fn = _grow_best_first_batched if best_first else _grow_level_wise_batched
        depth = fn(forest, t, binned, X_raw, stats, node_of, params, rng,
                   leaf_fn, num_lo, num_hi, backend)
    forest.depth = max(forest.depth, depth)
    return node_of


def _lockstep_ok(params: GrowthParams, num_lo) -> bool:
    """Lockstep (K trees per level pass) is semantics-free only when growth
    consumes no sequential rng: keyed (or no) feature sampling, no RANDOM
    categorical trials, no oblique projections — and level-wise strategy.
    The gathered bincount is a host-numpy formulation, so alternative
    histogram backends keep the per-tree path."""
    sp = params.splitter
    return (params.growing_strategy == "LOCAL"
            and sp.categorical_algorithm != "RANDOM"
            and not (sp.oblique and num_lo is not None)
            and (sp.num_candidate_ratio >= 1.0
                 or params.feature_sampling == "keyed")
            and resolve_backend(params.histogram_backend).name == "numpy")


def grow_trees(forest: Forest, ts, binned: BinnedFeatures, X_raw: np.ndarray,
               stats_list, actives, leaf_fn, params: GrowthParams, rngs,
               num_lo=None, num_hi=None, block: int | None = None
               ) -> np.ndarray:
    """Grow a block of independent trees (Random Forest §3.6). With the
    "device" engine or the lockstep host path the whole block advances one
    LEVEL at a time (tree axis through the frontier state); otherwise trees
    grow sequentially. All three produce identical forests when the sampling
    policy is keyed (tested), so blocking is purely an execution choice.
    ``block`` is the NOMINAL block width (e.g. tree_parallelism): the device
    engine pads a short final block up to it so every block reuses the same
    compiled programs. Returns per-tree final routing, (len(ts), N) int32."""
    engine, _ = resolve_engine(params, binned,
                               params.splitter.oblique and num_lo is not None)
    if engine == "device" and params.growing_strategy == "LOCAL":
        for b, t in enumerate(ts):
            forest.leaf_value[t, 0] = leaf_fn(stats_list[b][actives[b]].sum(0))
            forest.n_nodes[t] = 1
        from repro.core.grower_device import grow_trees_device
        return grow_trees_device(forest, ts, binned, stats_list, actives,
                                 leaf_fn, params, block=block or len(ts))
    if engine == "batched" and _lockstep_ok(params, num_lo) and len(ts) > 1:
        node_of = np.stack([np.where(a, 0, -1).astype(np.int32)
                            for a in actives])
        for b, t in enumerate(ts):
            forest.leaf_value[t, 0] = leaf_fn(stats_list[b][actives[b]].sum(0))
            forest.n_nodes[t] = 1
        _grow_level_wise_lockstep(forest, ts, binned, stats_list, node_of,
                                  params, leaf_fn)
        return node_of
    params_seq = (params if engine == params.engine
                  else dataclasses.replace(params, engine=engine))
    return np.stack([
        grow_tree(forest, t, binned, X_raw, stats_list[b], actives[b],
                  leaf_fn, params_seq, rngs[b], num_lo, num_hi)
        for b, t in enumerate(ts)])


def _node_best_split(hist_slice, binned, sp, rng, X_raw, stats, node_of_c,
                     n_slots, num_lo, num_hi, mask=None,
                     simple=False) -> list[Split]:
    splits = best_splits(hist_slice, binned, sp, rng, feature_mask=mask,
                         simple=simple)
    if sp.oblique and num_lo is not None:
        Fn = (~binned.is_cat).sum()
        if Fn:
            num_cols = np.where(~binned.is_cat)[0]
            obl = oblique_splits(X_raw[:, num_cols], num_lo, num_hi, stats,
                                 node_of_c, n_slots, sp, rng)
            for i in range(n_slots):
                if obl[i].gain > splits[i].gain:
                    o = obl[i]
                    # remap feature indices back to full-matrix columns
                    o.obl_features = num_cols[o.obl_features].astype(np.int32)
                    splits[i] = o
    return splits


# =====================================================================
# Batched-frontier engine (the fast path)
# =====================================================================

# Sibling-subtraction cache cap (both growth strategies): above this many
# cached float64s, histograms are rebuilt from scratch instead of cached.
_HIST_CACHE_BUDGET = 1 << 25  # 32M f64 = 256 MB


def _grow_level_wise_batched(forest, t, binned, X_raw, stats, node_of, params,
                             rng, leaf_fn, num_lo, num_hi,
                             backend: HistogramBackend) -> int:
    sp = params.splitter
    F = binned.n_features
    S = stats.shape[1]
    B = 256
    codes = binned.codes
    frontier = [0]
    depth = 0
    hist64 = None      # (n_front, F, B, S) f64 cache for sibling subtraction
    # per current slot: parent's previous-level slot and sibling's current
    # slot (-1 when the sibling left the frontier), example counts
    par_of = sib_of = n_ex = None
    for level in range(params.max_depth):
        if not frontier:
            break
        n_front = len(frontier)
        slot = np.full(forest.max_nodes, -1, np.int32)
        slot[np.asarray(frontier)] = np.arange(n_front, dtype=np.int32)
        node_of_c = np.where(node_of >= 0, slot[np.maximum(node_of, 0)], -1)
        hist64_prev, hist64 = hist64, None
        # subtraction pays only when accumulation (examples) outweighs the
        # per-level cache assembly (n_front * B buckets per feature-stat).
        # RANDOM categorical trials can tie exactly (masks differing only on
        # empty categories), where the subtraction's 1-ulp drift could flip
        # the argmax — build directly there to stay bit-identical.
        sub_pays = (par_of is not None
                    and backend.exact_subtraction
                    and sp.categorical_algorithm != "RANDOM"
                    and int(n_ex.sum()) > 4 * n_front * B)
        with trace.span("grower/hist_build", level=level, frontier=n_front,
                        subtraction=bool(sub_pays and hist64_prev is not None)):
            if hist64_prev is None or not sub_pays:
                hist64 = backend.build(codes, stats, node_of_c, n_front)
            else:
                # -- histogram subtraction across levels: accumulate only the
                # smaller child of each pair, derive the sibling as
                # parent - child
                build_slot = np.full(n_front, -1, np.int32)
                derive = []
                nb = 0
                for j in range(n_front):
                    sib = int(sib_of[j])
                    if sib < 0 or n_ex[j] < n_ex[sib] or (
                            n_ex[j] == n_ex[sib] and j < sib):
                        build_slot[j] = nb
                        nb += 1
                        if sib >= 0:
                            derive.append(sib)
                bmap = np.full(forest.max_nodes, -1, np.int32)
                bmap[np.asarray(frontier)] = build_slot
                node_of_b = np.where(node_of >= 0,
                                     bmap[np.maximum(node_of, 0)], -1)
                built = backend.build(codes, stats, node_of_b, nb)
                hist64 = np.empty((n_front, F, B, S), np.float64)
                built_rows = np.where(build_slot >= 0)[0]
                hist64[built_rows] = built[build_slot[built_rows]]
                if derive:
                    der = np.asarray(derive, np.int32)
                    hist64[der] = (hist64_prev[par_of[der]]
                                   - hist64[sib_of[der]])
                del hist64_prev
            hist = hist64.astype(np.float32)
        with trace.span("grower/gain_scan", level=level, frontier=n_front):
            mask = _candidate_mask(frontier, t, F, params, rng)
            splits = _node_best_split(hist, binned, sp, rng, X_raw, stats,
                                      node_of_c, n_front, num_lo, num_hi,
                                      mask)
        # -- allocate children (frontier order, shared node budget)
        left_of = np.full(n_front, -1, np.int32)
        for i, node in enumerate(frontier):
            s = splits[i]
            if not s.valid or forest.n_nodes[t] + 2 > params.max_nodes:
                continue
            left_of[i] = int(forest.n_nodes[t])
            forest.n_nodes[t] += 2
            _set_split(forest, t, node, s, binned)
            forest.left_child[t, node] = left_of[i]
            depth = level + 1
        split_slots = np.where(left_of >= 0)[0]
        if not len(split_slots):
            break
        # -- one vectorized apply_split pass over every routed example:
        # axis-aligned conditions collapse to a per-slot (256,) go-right
        # lookup over bin codes (b >= split_bin for numerical, set membership
        # for categorical); oblique slots fall back to per-slot projection.
        with trace.span("grower/routing", level=level,
                        splits=len(split_slots)):
            feat = np.array([s.feature for s in splits], np.int32)
            table = np.zeros((n_front, 256), bool)
            obl_slots = []
            for i in split_slots:
                s = splits[i]
                if s.obl_features is not None:
                    obl_slots.append(i)
                elif s.cat_right is not None:
                    table[i, s.cat_right] = True
                else:
                    table[i, s.split_bin:] = True
            ex = np.where((node_of_c >= 0)
                          & (left_of[np.maximum(node_of_c, 0)] >= 0))[0]
            sl = node_of_c[ex]
            go = table[sl, codes[ex, np.maximum(feat[sl], 0)]]
            for i in obl_slots:
                m = sl == i
                go[m] = apply_split(splits[i], binned, X_raw, ex[m])
            node_of[ex] = left_of[sl] + go
        # -- all child leaf stats in one flattened bincount over node_of
        with trace.span("grower/leaf_stats", level=level,
                        examples=len(ex)):
            ci_of = np.full(n_front, -1, np.int64)
            ci_of[split_slots] = np.arange(len(split_slots))
            child_code = 2 * ci_of[sl] + go
            n_child = 2 * len(split_slots)
            csum = np.bincount(
                (child_code[:, None] * S + np.arange(S)).ravel(),
                weights=np.ascontiguousarray(stats[ex], np.float64).ravel(),
                minlength=n_child * S).reshape(n_child, S)
            child_n_ex = np.bincount(child_code, minlength=n_child)
        # -- next frontier. A child below 2 * min_examples total weight can
        # never produce a valid split, so it is pruned from the frontier
        # (identical output, skipped work) — but only when the splitter
        # consumes no randomness the pruning could shift: the per-node
        # feature-sampling mask (one rng.choice per frontier node — unless
        # masks are KEYED by (tree, node), which pruning cannot perturb),
        # RANDOM categorical trials and oblique projections (per-level draws
        # that the oracle still makes for a frontier of unsplittable nodes).
        prune = ((sp.num_candidate_ratio >= 1.0
                  or params.feature_sampling == "keyed")
                 and sp.categorical_algorithm != "RANDOM"
                 and not (sp.oblique and num_lo is not None))
        keep = csum[:, -1] >= 2 * sp.min_examples if prune else \
            np.ones(n_child, bool)
        new_frontier = []
        par_l, sib_l, nex_l = [], [], []
        for ci, i in enumerate(split_slots):
            left = int(left_of[i])
            forest.leaf_value[t, left] = leaf_fn(csum[2 * ci])
            forest.leaf_value[t, left + 1] = leaf_fn(csum[2 * ci + 1])
            kl, kr = bool(keep[2 * ci]), bool(keep[2 * ci + 1])
            jl = len(new_frontier)
            jr = jl + kl
            if kl:
                new_frontier.append(left)
                par_l.append(i)
                sib_l.append(jr if kr else -1)
                nex_l.append(child_n_ex[2 * ci])
            if kr:
                new_frontier.append(left + 1)
                par_l.append(i)
                sib_l.append(jl if kl else -1)
                nex_l.append(child_n_ex[2 * ci + 1])
        frontier = new_frontier
        if (len(new_frontier) * F * B * S > _HIST_CACHE_BUDGET):
            hist64 = None  # cache too large: next level rebuilds from scratch
        par_of = np.asarray(par_l, np.int32)
        sib_of = np.asarray(sib_l, np.int32)
        n_ex = np.asarray(nex_l, np.int64)
    return depth


def _grow_best_first_batched(forest, t, binned, X_raw, stats, node_of, params,
                             rng, leaf_fn, num_lo, num_hi,
                             backend: HistogramBackend) -> int:
    """Leaf-wise growth with the parent-minus-sibling subtraction trick.

    The heap holds (-gain, counter, node, depth, Split); a side store keeps,
    per open leaf, its example index list and float64 histogram. On split,
    only the smaller child's histogram is accumulated (over its own examples)
    and the sibling's is derived as ``parent - child`` — O(smaller child)
    per split instead of two O(N) passes.
    """
    sp = params.splitter
    F = binned.n_features
    N = binned.codes.shape[0]
    oblique = sp.oblique and num_lo is not None

    def build(idx: np.ndarray) -> np.ndarray:
        with trace.span("grower/hist_build", examples=len(idx)):
            return backend.build(binned.codes[idx], stats[idx],
                                 np.zeros(len(idx), np.int32), 1)

    def eval_node(node: int, idx: np.ndarray, hist64: np.ndarray) -> Split:
        with trace.span("grower/gain_scan", node=node):
            m = _candidate_mask([node], t, F, params, rng)
            node_of_c = None
            if oblique:  # oblique projections scan raw columns, not hists
                node_of_c = np.full(N, -1, np.int32)
                node_of_c[idx] = 0
            return _node_best_split(hist64.astype(np.float32), binned, sp,
                                    rng, X_raw, stats, node_of_c, 1, num_lo,
                                    num_hi, m)[0]

    heap: list = []
    counter = 0
    # per open leaf: (example indices, f64 histogram or None). Histograms are
    # cached only while the total stays under _HIST_CACHE_BUDGET; evicted
    # entries (None) are rebuilt from the index list on pop.
    store: dict[int, tuple[np.ndarray, np.ndarray | None]] = {}
    hist_elems = F * 256 * stats.shape[1]
    cached = 0

    def stash(node: int, idx: np.ndarray, hist64: np.ndarray) -> None:
        nonlocal cached
        if (cached + 1) * hist_elems <= _HIST_CACHE_BUDGET:
            store[node] = (idx, hist64)
            cached += 1
        else:
            store[node] = (idx, None)

    root_idx = np.where(node_of == 0)[0]
    h0 = build(root_idx)
    s0 = eval_node(0, root_idx, h0)
    if s0.valid:
        heapq.heappush(heap, (-s0.gain, counter, 0, 0, s0))
        counter += 1
        stash(0, root_idx, h0)
    depth = 0
    while heap and forest.n_nodes[t] + 2 <= params.max_nodes:
        ngain, _, node, d, s = heapq.heappop(heap)
        idx, hist_p = store.pop(node)
        if hist_p is None:
            hist_p = build(idx)
        else:
            cached -= 1
        left = int(forest.n_nodes[t])
        forest.n_nodes[t] += 2
        _set_split(forest, t, node, s, binned)
        forest.left_child[t, node] = left
        with trace.span("grower/routing", node=node, examples=len(idx)):
            go = apply_split(s, binned, X_raw, idx)
            node_of[idx] = np.where(go, left + 1, left)
        depth = max(depth, d + 1)
        child_idx = {left: idx[~go], left + 1: idx[go]}
        with trace.span("grower/leaf_stats", node=node):
            for child, cidx in child_idx.items():
                forest.leaf_value[t, child] = leaf_fn(stats[cidx].sum(0))
        want = {c: d + 1 < params.max_depth and len(ci) >= 2 * sp.min_examples
                for c, ci in child_idx.items()}
        if not any(want.values()):
            continue
        small = min((left, left + 1), key=lambda c: len(child_idx[c]))
        big = 2 * left + 1 - small
        hists = {small: build(child_idx[small])}
        if want[big]:
            # Build directly instead of subtracting when the backend does
            # not accumulate in f64, or under RANDOM categoricals, whose
            # trials can tie exactly (a 1-ulp drift could flip the argmax)
            if (sp.categorical_algorithm == "RANDOM"
                    or not backend.exact_subtraction):
                hists[big] = build(child_idx[big])
            else:
                hists[big] = hist_p - hists[small]
        for child in (left, left + 1):  # fixed order keeps the rng sequence
            if not want[child]:
                continue
            cs = eval_node(child, child_idx[child], hists[child])
            if cs.valid:
                heapq.heappush(heap, (-cs.gain, counter, child, d + 1, cs))
                counter += 1
                stash(child, child_idx[child], hists[child])
    return depth


def _grow_level_wise_lockstep(forest, ts, binned, stats_list, node_of,
                              params, leaf_fn) -> None:
    """Level-wise growth of K independent trees in lockstep (DESIGN.md §6.3).

    The frontier spans (tree, node) slots; one gathered bincount accumulates
    every tree's histograms and one gathered scan finds every best split.
    Because per-node candidate features are KEYED (sampling.py) and only the
    sampled columns are gathered, the histogram+scan cost is ``k/F`` of the
    full-matrix pass (k = sqrt(F) under the Breiman rule) — the optimization
    that makes Random Forest growth pay, single tree or lockstep.

    Requires _lockstep_ok (no sequential rng in growth): under that
    precondition the result is bit-identical to growing the trees one at a
    time with the oracle engine (tested in tests/test_grower_device.py).
    """
    sp = params.splitter
    K = len(ts)
    F = binned.n_features
    B = 256
    codes = binned.codes
    sample = sp.num_candidate_ratio < 1.0
    kf = sample_size(sp.num_candidate_ratio, F) if sample else F
    stats64 = [np.ascontiguousarray(s, np.float64) for s in stats_list]
    S = stats64[0].shape[1]
    frontiers: list[list[int]] = [[0] for _ in ts]
    depths = [0] * K
    ident = np.broadcast_to(np.arange(F, dtype=np.int32), (1, F))
    for level in range(params.max_depth):
        n_slots_k = [len(f) for f in frontiers]
        n_slots = sum(n_slots_k)
        if n_slots == 0:
            break
        base = np.concatenate([[0], np.cumsum(n_slots_k)]).astype(np.int64)
        if sample:
            feat_sel = np.concatenate(
                [keyed_feature_select(params.sampling_key, int(ts[k]),
                                      np.asarray(frontiers[k], np.int64), F, kf)
                 for k in range(K) if n_slots_k[k]])
        else:
            feat_sel = np.broadcast_to(ident, (n_slots, F))
        # -- gather each tree's frontier examples + their sampled codes
        ex_k: list = [None] * K
        slot_k: list = [None] * K                 # local slot per example
        for k in range(K):
            if not n_slots_k[k]:
                continue
            slotmap = np.full(forest.max_nodes, -1, np.int32)
            slotmap[np.asarray(frontiers[k])] = np.arange(n_slots_k[k],
                                                          dtype=np.int32)
            sl = np.where(node_of[k] >= 0,
                          slotmap[np.maximum(node_of[k], 0)], -1)
            ex = np.where(sl >= 0)[0]
            ex_k[k], slot_k[k] = ex, sl[ex]
        ex_all = np.concatenate([e for e in ex_k if e is not None])
        gslot = np.concatenate([slot_k[k] + base[k] for k in range(K)
                                if ex_k[k] is not None]).astype(np.int64)
        codes_sel = codes[ex_all[:, None], feat_sel[gslot]]      # (n_ex, kf)
        wstats = np.concatenate([stats64[k][ex_k[k]] for k in range(K)
                                 if ex_k[k] is not None])
        # -- one flattened bincount over (slot, candidate, bin) buckets; per
        # bucket the accumulation order stays example-ascending within one
        # tree, bit-identical to the per-tree numpy backend
        with trace.span("grower/hist_build", level=level, lockstep=K,
                        frontier=n_slots):
            flat = ((gslot[:, None] * kf + np.arange(kf)[None]) * B
                    + codes_sel).ravel()
            uniq, inv = _unique_stat_columns(wstats)
            strips = [np.bincount(flat, weights=np.repeat(wstats[:, s], kf),
                                  minlength=n_slots * kf * B
                                  ).reshape(n_slots, kf, B) for s in uniq]
            hist = np.empty((n_slots, kf, B, S), np.float32)
            for s in range(S):
                hist[..., s] = strips[inv[s]]
        with trace.span("grower/gain_scan", level=level, lockstep=K,
                        frontier=n_slots):
            splits = best_splits_gathered(hist, feat_sel, binned, sp)
        # -- per tree: allocate children, route, child stats, prune
        _route_ctx = trace.span("grower/routing", level=level, lockstep=K)
        _route_ctx.__enter__()
        for k in range(K):
            n_k = n_slots_k[k]
            if not n_k:
                continue
            t = ts[k]
            spl = splits[base[k]:base[k + 1]]
            left_of = np.full(n_k, -1, np.int32)
            for i, node in enumerate(frontiers[k]):
                s = spl[i]
                if not s.valid or forest.n_nodes[t] + 2 > params.max_nodes:
                    continue
                left_of[i] = int(forest.n_nodes[t])
                forest.n_nodes[t] += 2
                _set_split(forest, t, node, s, binned)
                forest.left_child[t, node] = left_of[i]
                depths[k] = level + 1
            split_slots = np.where(left_of >= 0)[0]
            if not len(split_slots):
                frontiers[k] = []
                continue
            feat = np.array([s.feature for s in spl], np.int32)
            table = np.zeros((n_k, 256), bool)
            for i in split_slots:
                s = spl[i]
                if s.cat_right is not None:
                    table[i, s.cat_right] = True
                else:
                    table[i, s.split_bin:] = True
            m = left_of[slot_k[k]] >= 0
            ex, sl = ex_k[k][m], slot_k[k][m]
            go = table[sl, codes[ex, np.maximum(feat[sl], 0)]]
            node_of[k][ex] = left_of[sl] + go
            ci_of = np.full(n_k, -1, np.int64)
            ci_of[split_slots] = np.arange(len(split_slots))
            child_code = 2 * ci_of[sl] + go
            n_child = 2 * len(split_slots)
            csum = np.bincount(
                (child_code[:, None] * S + np.arange(S)).ravel(),
                weights=np.ascontiguousarray(stats64[k][ex]).ravel(),
                minlength=n_child * S).reshape(n_child, S)
            keep = csum[:, -1] >= 2 * sp.min_examples
            nf = []
            for ci, i in enumerate(split_slots):
                left = int(left_of[i])
                forest.leaf_value[t, left] = leaf_fn(csum[2 * ci])
                forest.leaf_value[t, left + 1] = leaf_fn(csum[2 * ci + 1])
                if keep[2 * ci]:
                    nf.append(left)
                if keep[2 * ci + 1]:
                    nf.append(left + 1)
            frontiers[k] = nf
        _route_ctx.__exit__(None, None, None)
    for d in depths:
        forest.depth = max(forest.depth, d)


# =====================================================================
# Oracle engine — the seed-equivalent simple module (paper §2.3)
# =====================================================================

def _grow_level_wise_oracle(forest, t, binned, X_raw, stats, node_of, params,
                            rng, leaf_fn, num_lo, num_hi) -> int:
    sp = params.splitter
    F = binned.n_features
    frontier = [0]
    depth = 0
    for level in range(params.max_depth):
        if not frontier:
            break
        slot_of_node = {n: i for i, n in enumerate(frontier)}
        slot = np.full(forest.max_nodes, -1, np.int32)
        for n, i in slot_of_node.items():
            slot[n] = i
        node_of_c = np.where(node_of >= 0, slot[np.maximum(node_of, 0)], -1)
        hist = build_histogram(binned.codes, stats, node_of_c, len(frontier),
                               backend="simple")
        mask = _candidate_mask(frontier, t, F, params, rng)
        splits = _node_best_split(hist, binned, sp, rng, X_raw, stats,
                                  node_of_c, len(frontier), num_lo, num_hi,
                                  mask, simple=True)
        new_frontier = []
        for i, node in enumerate(frontier):
            s = splits[i]
            if not s.valid or forest.n_nodes[t] + 2 > params.max_nodes:
                continue
            left = int(forest.n_nodes[t])
            forest.n_nodes[t] += 2
            _set_split(forest, t, node, s, binned)
            forest.left_child[t, node] = left
            idx = np.where(node_of == node)[0]
            go = apply_split(s, binned, X_raw, idx)
            node_of[idx] = np.where(go, left + 1, left)
            for child, sel in ((left, ~go), (left + 1, go)):
                cs = stats[idx[sel]].sum(0)
                forest.leaf_value[t, child] = leaf_fn(cs)
                new_frontier.append(child)
            depth = level + 1
        frontier = new_frontier
    return depth


def _grow_best_first_oracle(forest, t, binned, X_raw, stats, node_of, params,
                            rng, leaf_fn, num_lo, num_hi) -> int:
    """Leaf-wise growth. Heap holds (-gain, node, depth, Split)."""
    sp = params.splitter
    F = binned.n_features

    def eval_node(node: int) -> Split:
        mask01 = (node_of == node).astype(np.int32)
        node_of_c = np.where(mask01 > 0, 0, -1).astype(np.int32)
        hist = build_histogram(binned.codes, stats, node_of_c, 1,
                               backend="simple")
        m = _candidate_mask([node], t, F, params, rng)
        return _node_best_split(hist, binned, sp, rng, X_raw, stats, node_of_c,
                                1, num_lo, num_hi, m, simple=True)[0]

    heap: list = []
    counter = 0
    s0 = eval_node(0)
    if s0.valid:
        heapq.heappush(heap, (-s0.gain, counter, 0, 0, s0))
        counter += 1
    depth = 0
    while heap and forest.n_nodes[t] + 2 <= params.max_nodes:
        ngain, _, node, d, s = heapq.heappop(heap)
        left = int(forest.n_nodes[t])
        forest.n_nodes[t] += 2
        _set_split(forest, t, node, s, binned)
        forest.left_child[t, node] = left
        idx = np.where(node_of == node)[0]
        go = apply_split(s, binned, X_raw, idx)
        node_of[idx] = np.where(go, left + 1, left)
        depth = max(depth, d + 1)
        for child in (left, left + 1):
            cidx = np.where(node_of == child)[0]
            forest.leaf_value[t, child] = leaf_fn(stats[cidx].sum(0))
            if d + 1 < params.max_depth and len(cidx) >= 2 * sp.min_examples:
                cs = eval_node(child)
                if cs.valid:
                    heapq.heappush(heap, (-cs.gain, counter, child, d + 1, cs))
                    counter += 1
    return depth
