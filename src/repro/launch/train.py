"""Training launcher.

  python -m repro.launch.train --arch qwen2-1.5b --smoke --steps 50
  python -m repro.launch.train --arch qwen3-8b --shape train_4k --mesh single

On the production meshes this wires the same train_loop used by tests into
the 16x16 / 2x16x16 shardings (run under real XLA devices on hardware; here
the mesh paths are exercised by the dry-run and the 8-device subprocess
tests). XLA latency-hiding/overlap flags are plumbed here.
"""
from __future__ import annotations

import argparse
import os


OVERLAP_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-sized)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--overlap-flags", action="store_true",
                    help="enable the XLA latency-hiding scheduler (TPU)")
    args = ap.parse_args()

    if args.overlap_flags:
        os.environ["XLA_FLAGS"] = OVERLAP_FLAGS + os.environ.get("XLA_FLAGS", "")

    from repro.configs import SHAPES, get_arch, smoke_config
    from repro.configs.base import ShapeConfig
    from repro.sharding import rules_for
    from repro.train.loop import LoopConfig, train_loop

    cfg = get_arch(args.arch)
    shape = SHAPES[args.shape]
    mesh = rules = None
    if args.smoke:
        cfg = smoke_config(cfg)
        shape = ShapeConfig("smoke", "train", args.seq or 128, args.batch or 4)
    elif args.batch or args.seq:
        shape = ShapeConfig("custom", "train", args.seq or shape.seq_len,
                            args.batch or shape.global_batch)
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        rules = rules_for("train")

    out = train_loop(cfg, shape, os.path.join(args.ckpt, args.arch),
                     LoopConfig(total_steps=args.steps), mesh=mesh, rules=rules)
    print(f"done: {out['final_step']} steps; last losses: {out['losses'][-3:]}")


if __name__ == "__main__":
    main()
