import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod AOT dry-run.

For every (architecture x applicable shape x mesh) cell:
  jit(step).lower(ShapeDtypeStructs...).compile()
on 512 placeholder host devices — proving the sharding config is coherent
(no allocation happens), then records memory/cost analyses and the collective
schedule for the roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-done]   # driver

The driver runs each cell in a fresh subprocess (compile memory isolation on
the 1-core host) and writes results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import subprocess
import sys
from repro.obs import clock
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def _cell_path(arch: str, shape: str, mesh: str, suffix: str = "") -> str:
    name = f"{arch}__{shape}__{mesh}{('__' + suffix) if suffix else ''}.json"
    return os.path.abspath(os.path.join(RESULTS_DIR, name))


def run_cell(arch_name: str, shape_name: str, mesh_kind: str, out_path: str | None,
             overrides: dict | None = None,
             rules_overrides: dict | None = None) -> dict:
    import jax
    import numpy as np

    from repro.configs import SHAPES, applicable_shapes, get_arch
    from repro.launch.mesh import HBM_PER_CHIP, make_production_mesh
    from repro.launch.roofline import derive_terms, parse_collectives
    from repro.models import lm
    from repro.serving import make_decode_step, make_prefill, serve_state_specs
    from repro.sharding import resolve_spec, rules_for, tree_shardings
    from repro.train import make_train_step

    cfg = get_arch(arch_name)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    assert shape_name in applicable_shapes(cfg), \
        f"{shape_name} not applicable to {arch_name} (see DESIGN.md §Arch-applicability)"
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    long_ctx = shape.seq_len >= 2 ** 19
    rules = rules_for("train" if shape.kind == "train" else "serve", long_context=long_ctx)
    if rules_overrides:
        rules.update(rules_overrides)

    t0 = clock.wall()
    if shape.kind == "train":
        bundle = make_train_step(cfg, shape, mesh, rules)
        arg_specs = (bundle.state_specs, lm.batch_spec(cfg, shape))
        arg_sh = (bundle.state_shardings, bundle.batch_shardings)
        jf = jax.jit(bundle.step_fn, in_shardings=arg_sh, donate_argnums=(0,))
    elif shape.kind == "prefill":
        b = make_prefill(cfg, shape, mesh, rules)
        p_specs, _ = serve_state_specs(cfg)
        arg_specs = (p_specs, lm.batch_spec(cfg, shape))
        arg_sh = (b.param_shardings, b.batch_shardings)
        jf = jax.jit(b.fn, in_shardings=arg_sh)
    else:  # decode
        b = make_decode_step(cfg, shape, mesh, rules)
        p_specs, _ = serve_state_specs(cfg)
        arg_specs = (p_specs, lm.batch_spec(cfg, shape),
                     lm.cache_spec(cfg, shape.global_batch, shape.seq_len))
        arg_sh = (b.param_shardings, b.batch_shardings, b.cache_shardings)
        jf = jax.jit(b.fn, in_shardings=arg_sh, donate_argnums=(2,))

    lowered = jf.lower(*arg_specs)
    t_lower = clock.wall() - t0
    compiled = lowered.compile()
    t_compile = clock.wall() - t0 - t_lower

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    mem_report = None
    try:
        ma = compiled.memory_analysis()
        mem_report = {k: int(getattr(ma, k)) for k in dir(ma)
                      if k.endswith("size_in_bytes") and isinstance(getattr(ma, k), int)}
    except Exception as e:  # CPU backend may not implement it
        mem_report = {"unavailable": str(e)[:200]}

    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    terms = derive_terms(cost, coll, cfg, shape, mesh.size)

    # Analytic per-device input bytes (params/opt/cache/batch after sharding):
    def shard_bytes(tree, axes_tree):
        import jax.numpy as jnp
        total = 0
        leaves, treedef = jax.tree.flatten(tree)
        sh_leaves = treedef.flatten_up_to(axes_tree) if axes_tree is not None else [None] * len(leaves)
        for leaf, sh in zip(leaves, sh_leaves):
            n = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
            if sh is not None:
                spec = sh.spec if hasattr(sh, "spec") else None
                if spec is not None:
                    denom = 1
                    for part in spec:
                        if part is None:
                            continue
                        for ax in (part if isinstance(part, tuple) else (part,)):
                            denom *= mesh.shape[ax]
                    n = -(-n // denom)
            total += n
        return total

    input_bytes = sum(shard_bytes(s, sh) for s, sh in zip(arg_specs, arg_sh))

    print(f"== {arch_name} x {shape_name} x {mesh_kind} ({mesh.shape}) ==")
    print(f"memory_analysis: {mem_report}")
    print(f"cost_analysis: flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")
    print(f"collectives: {coll['count_by_kind']} -> {coll['total_bytes']:.3e} B/device")
    print(f"input bytes/device: {input_bytes:.3e} "
          f"({input_bytes / HBM_PER_CHIP * 100:.1f}% of 16GiB HBM)")
    print(f"terms: compute={terms.compute_s:.4e}s memory={terms.memory_s:.4e}s "
          f"collective={terms.collective_s:.4e}s dominant={terms.dominant} "
          f"useful_ratio={terms.useful_ratio:.3f} roofline_frac={terms.roofline_fraction:.3f}")

    record = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "chips": mesh.size,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": {k: v for k, v in cost.items()
                          if isinstance(v, (int, float)) and v},
        "memory_analysis": mem_report,
        "collectives": coll,
        "input_bytes_per_device": input_bytes,
        "terms": {
            "compute_s": terms.compute_s, "memory_s": terms.memory_s,
            "collective_s": terms.collective_s, "dominant": terms.dominant,
            "flops_per_device": terms.flops_per_device,
            "bytes_per_device": terms.bytes_per_device,
            "coll_bytes_per_device": terms.coll_bytes_per_device,
            "model_flops": terms.model_flops,
            "useful_ratio": terms.useful_ratio,
            "roofline_fraction": terms.roofline_fraction,
        },
        "overrides": overrides or {},
        "rules_overrides": {k: list(v) for k, v in (rules_overrides or {}).items()},
    }
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(record, f, indent=1)
    return record


def all_cells(mesh_kinds=("single", "multi")):
    from repro.configs import applicable_shapes, get_arch, list_archs
    for arch in list_archs():
        for shape in applicable_shapes(get_arch(arch)):
            for mk in mesh_kinds:
                yield arch, shape, mk


def driver(mesh_kinds, skip_done: bool, overrides=(), suffix: str = "") -> int:
    cells = list(all_cells(mesh_kinds))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    failures = 0
    for i, (arch, shape, mk) in enumerate(cells):
        out = _cell_path(arch, shape, mk, suffix)
        if skip_done and os.path.exists(out):
            continue
        t0 = clock.wall()
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mk, "--out", out]
        for ov in overrides:
            cmd += ["--override", ov]
        r = subprocess.run(
            cmd, capture_output=True, text=True,
            env=dict(os.environ, PYTHONPATH=os.environ.get("PYTHONPATH", "src")),
        )
        dt = clock.wall() - t0
        status = "ok" if r.returncode == 0 else "FAIL"
        print(f"[{i + 1}/{len(cells)}] {arch} x {shape} x {mk}: {status} ({dt:.0f}s)",
              flush=True)
        if r.returncode != 0:
            failures += 1
            err_path = out.replace(".json", ".err")
            with open(err_path, "w") as f:
                f.write(r.stdout[-5000:] + "\n---\n" + r.stderr[-10000:])
            print(r.stderr[-2000:], flush=True)
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (perf iterations)")
    ap.add_argument("--rules-override", action="append", default=[],
                    help="sharding rule override logical=axis1,axis2 (perf)")
    ap.add_argument("--suffix", default="", help="result-file suffix (driver mode)")
    args = ap.parse_args()

    kinds = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        sys.exit(1 if driver(kinds, args.skip_done, args.override, args.suffix) else 0)

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "False"):
            v = v == "True"
        overrides[k] = v
    rules_overrides = {}
    for ov in args.rules_override:
        k, v = ov.split("=", 1)
        rules_overrides[k] = tuple(a for a in v.split(",") if a)

    for mk in kinds:
        out = args.out or _cell_path(args.arch, args.shape, mk)
        run_cell(args.arch, args.shape, mk, out, overrides, rules_overrides)


if __name__ == "__main__":
    main()
