"""Production mesh construction.

A *function*, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and smoke tests
must see 1 CPU device while the dry-run forces 512 placeholders).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic/degraded mesh shapes (restart after node loss, tests)."""
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants (targets for the roofline; the host is CPU-only).
PEAK_BF16_FLOPS = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per direction)
HBM_PER_CHIP = 16 * 1024**3   # 16 GiB
