"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = sum(bytes moved per device over ICI) / link_bw

``compiled.cost_analysis()`` reports the *per-device* (post-SPMD) module, so
per-device terms divide by single-chip peaks; the prompt's global form
(HLO_FLOPs_global / (chips x peak)) is identical because
HLO_FLOPs_global = per_device x chips.

Collective bytes are NOT in cost_analysis: we parse the post-partitioning HLO
text and sum shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with ring multipliers per op kind.

MODEL_FLOPS (the "useful" floor) = 6*N*D for dense training, 6*N_active*D for
MoE, 2*N(_active)*tokens for forward-only (prefill/decode); the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_BF16_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # unknown -> conservative


def parse_collectives(hlo_text: str) -> dict:
    """Per-device bytes moved over ICI, by collective kind (ring estimates)."""
    by_kind: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        result_bytes = _shape_bytes(m.group("shapes"))
        n = max(2, _group_size(line))
        ring = (n - 1) / n
        if op == "all-reduce":
            moved = 2.0 * result_bytes * ring          # RS + AG, result==operand
        elif op == "all-gather":
            moved = result_bytes * ring                # result = gathered
        elif op == "reduce-scatter":
            moved = result_bytes * (n - 1)             # operand = result*n
        elif op == "all-to-all":
            moved = result_bytes * ring
        else:  # collective-permute
            moved = result_bytes
        by_kind[op] = by_kind.get(op, 0.0) + moved
        count[op] = count.get(op, 0) + 1
    return {"bytes_by_kind": by_kind, "count_by_kind": count,
            "total_bytes": sum(by_kind.values())}


# --------------------------------------------------------------- model flops

def count_params(cfg: ModelConfig) -> tuple[int, int]:
    """(total params, active-per-token params). MoE experts scale by top_k/E."""
    from repro.models import lm
    from repro.models.params import is_spec
    import jax
    import numpy as np

    sch = lm.model_schema(cfg)
    total = active = 0
    for path, spec in jax.tree_util.tree_flatten_with_path(sch, is_leaf=is_spec)[0]:
        n = int(np.prod(spec.shape))
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        in_moe = "moe" in keys and "shared" not in keys and spec.shape and \
            cfg.n_experts and any(d == cfg.n_experts for d in spec.shape[:3])
        active += int(n * cfg.top_k / cfg.n_experts) if in_moe else n
    return total, active


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    _, n_active = count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch


# --------------------------------------------------------------- terms

@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops (remat/redundancy waste detector)."""
        g = self.flops_per_device * self.chips
        return self.model_flops / g if g else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / achievable step time (higher is better)."""
        ideal = self.model_flops / self.chips / PEAK_BF16_FLOPS
        return ideal / self.bound_s if self.bound_s else 0.0


def derive_terms(cost: dict, coll: dict, cfg: ModelConfig, shape: ShapeConfig,
                 chips: int) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll["total_bytes"])
    return RooflineTerms(
        compute_s=flops / PEAK_BF16_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=cbytes / ICI_BW,
        flops_per_device=flops,
        bytes_per_device=byts,
        coll_bytes_per_device=cbytes,
        model_flops=model_flops(cfg, shape),
        chips=chips,
    )
