"""Serving launcher: prefill a batch of prompts, then decode greedily,
reporting tokens/s. CPU-sized with --smoke; production shardings via --mesh
(exercised by the dry-run on this host).
"""
from __future__ import annotations

import argparse
from repro.obs import clock


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, smoke_config
    from repro.configs.base import ShapeConfig
    from repro.models import lm
    from repro.models.layers import Ctx
    from repro.models.params import init_params
    from repro.serving.decode import greedy_generate

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    shape = ShapeConfig("serve", "prefill", args.prompt_len, args.batch)
    params = init_params(jax.random.key(0), lm.model_schema(cfg), cfg.param_dtype)
    batch = lm.make_batch(jax.random.key(1), cfg, shape)

    t0 = clock.wall()
    toks = greedy_generate(params, batch, cfg, args.gen)
    dt = clock.wall() - t0
    n_tok = toks.shape[0] * toks.shape[1]
    print(f"{args.arch}: generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s incl. compile)")
    print("sample token ids:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
