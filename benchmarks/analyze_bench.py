"""Analysis-throughput benchmark: permutation variable importance on a
300-tree depth-12 Random Forest, the compiled batched-replica path vs a
naive per-feature predict loop. Writes BENCH_analyze.json (the analysis
perf-trajectory baseline, tracked like BENCH_infer.json; DESIGN.md §8).

"naive"   = the per-feature python loop over the SEED per-call path: every
(feature, repetition) replica starts from the raw columns, permutes one of
them, then pays the full per-call pipeline — encode_dataset dataspec walk,
raw_matrix imputation pass, generic lockstep traversal (tree.predict_raw)
— exactly what hand-rolling permutation importance against the seed predict
path costs.
"batched" = analysis.permutation_importances: encode ONCE through the
compiled predictor's BatchEncoder, stack all F x R permuted replicas into
row-budget-bounded batches, and dispatch them through the cached
CompiledPredictor (§5.1 specialized traversal).

Both paths draw each replica's permutation from the same keyed rng
(importance._permutation), and elementwise encoding commutes with row
permutation, so the two score vectors must agree to numerical tolerance —
checked and recorded alongside the timings.

Usage: python benchmarks/analyze_bench.py [--rows N] [--trees T] [--quick]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

from repro.analysis.importance import _permutation, permutation_importances
from repro.core import RandomForestLearner
from repro.core.dataspec import encode_dataset, label_values
from repro.core.models import raw_matrix
from repro.core.tree import predict_raw
from repro.data.tabular import adult_like


def _naive_loop(model, data, repetitions: int, seed: int) -> dict[str, float]:
    """Per-feature loop over the seed per-call path; returns feature ->
    mean decrease in accuracy."""
    y = label_values(model, data)
    N = len(y)

    def seed_predict(batch):
        ds = encode_dataset(batch, model.spec)
        X = raw_matrix(ds, model.features)
        return model._finalize(predict_raw(model.forest, X))

    base_acc = float((seed_predict(data).argmax(1) == y).mean())
    out = {}
    for j, name in enumerate(model.features):
        drops = []
        for r in range(repetitions):
            perm = _permutation(seed, j, r, N)
            batch = dict(data)
            batch[name] = np.asarray(data[name], dtype=object)[perm]
            acc = float((seed_predict(batch).argmax(1) == y).mean())
            drops.append(base_acc - acc)
        out[name] = float(np.mean(drops))
    return out


def run(rows: int = 2000, num_trees: int = 300, max_depth: int = 12,
        repetitions: int = 2, reps: int = 2, seed: int = 42,
        row_budget: int | None = None, verbose: bool = True) -> dict:
    import jax
    train = adult_like(max(3000, rows), seed=1)
    data = {k: v[:rows] for k, v in adult_like(rows, seed=9).items()}

    t0 = time.perf_counter()
    model = RandomForestLearner(label="income", num_trees=num_trees,
                                max_depth=max_depth).train(train)
    train_s = time.perf_counter() - t0
    model.predictor()  # compile outside the timed region (paid once, §5.1)

    # interleaved best-of-reps (train_bench protocol): background load on the
    # shared host perturbs both candidates equally
    best_naive = best_batched = np.inf
    naive_scores = batched_table = None
    for _ in range(reps):
        t0 = time.perf_counter()
        naive_scores = _naive_loop(model, data, repetitions, seed)
        best_naive = min(best_naive, time.perf_counter() - t0)
        t0 = time.perf_counter()
        kw = {} if row_budget is None else {"row_budget": row_budget}
        batched_table, _ = permutation_importances(
            model, data, repetitions=repetitions, seed=seed, **kw)
        best_batched = min(best_batched, time.perf_counter() - t0)

    diffs = [abs(naive_scores[f] - batched_table[f])
             for f in model.features]
    n_replicas = len(model.features) * repetitions
    out = {
        "benchmark": "analyze_bench",
        "host": {"platform": platform.platform(), "numpy": np.__version__,
                 "jax_backend": jax.default_backend()},
        "config": {"rows": rows, "num_trees": num_trees,
                   "max_depth": max_depth, "repetitions": repetitions,
                   "n_features": len(model.features),
                   "total_nodes": int(model.forest.n_nodes.sum()),
                   "train_s": round(train_s, 2)},
        "naive_loop_s": round(best_naive, 3),
        "batched_replicas_s": round(best_batched, 3),
        "us_per_replica_row_naive": round(
            best_naive / (n_replicas * rows) * 1e6, 3),
        "us_per_replica_row_batched": round(
            best_batched / (n_replicas * rows) * 1e6, 3),
        "speedup": round(best_naive / best_batched, 3),
        "max_score_diff": float(max(diffs)),
        "scores_match": bool(max(diffs) < 1e-9),
        "top_feature": batched_table.ranking()[0],
    }
    if verbose:
        print(f"  permutation importance ({num_trees} trees, depth "
              f"{max_depth}, {rows} rows x {n_replicas} replicas): "
              f"naive {best_naive:.2f}s, batched {best_batched:.2f}s, "
              f"speedup {out['speedup']:.2f}x, "
              f"match {out['scores_match']}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=2000)
    ap.add_argument("--trees", type=int, default=300)
    ap.add_argument("--depth", type=int, default=12)
    ap.add_argument("--repetitions", type=int, default=2)
    ap.add_argument("--reps", type=int, default=2,
                    help="timing repetitions (best-of)")
    ap.add_argument("--quick", action="store_true",
                    help="small configuration for CI smoke")
    ap.add_argument("--out", default="BENCH_analyze.json")
    args = ap.parse_args()
    if args.quick:
        res = run(rows=400, num_trees=30, max_depth=8, repetitions=1, reps=1)
    else:
        res = run(rows=args.rows, num_trees=args.trees, max_depth=args.depth,
                  repetitions=args.repetitions, reps=args.reps)
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)
        print(f"headline (compiled batched replicas vs naive per-feature "
              f"loop): {res['speedup']:.2f}x -> {args.out}")


if __name__ == "__main__":
    main()
