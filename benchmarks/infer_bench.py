"""Inference-throughput benchmark: the compiled serving stack vs the seed
per-call path. Writes BENCH_infer.json (the serving perf-trajectory
baseline, tracked like BENCH_train.json; paper Tab. 2 analogue for
*inference* — see DESIGN.md §5, §10).

"before" = the seed path: every predict call re-walks the dataspec
(encode_dataset), re-imputes into a raw matrix (raw_matrix) and runs the
generic lockstep traversal (tree.predict_raw) — per-call conversion, no
reuse.
"after"  = CompiledPredictor.predict per engine (§5.1/§10): raw→code encode
tables, specialized/device-resident traversal and the output head compiled
once, then reused for every request batch. Every CPU traversal strategy
(vectorized numpy, depth-bucketed XLA scan, forced leaf-path matmul) gets
its own column so the per-strategy trajectory is tracked, not just the
winner.

Every timed pair is checked for allclose predictions (the §2.3 contract).
Engine compile time is reported separately (it is paid once, not per call).

The ``sklearn_import`` config (DESIGN.md §7) times an imported 300-tree
sklearn RandomForest through our compiled predictor against sklearn's own
``predict_proba`` on the same rows — the cross-runtime serving comparison
(Guan et al., 2023 protocol). ``speedup_vs_sklearn`` (the tracked headline)
is the BEST strategy's ratio; per-strategy ratios are recorded alongside.
It runs whenever scikit-learn is installed (an optional dependency) and is
skipped cleanly otherwise.

Usage: python benchmarks/infer_bench.py [--rows N] [--trees T] [--out PATH]
       [--quick]   (tiny smoke sizes; also exercised inside tier-1 tests)
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

from repro.core import GradientBoostedTreesLearner, RandomForestLearner
from repro.core.dataspec import encode_dataset
from repro.core.models import raw_matrix
from repro.core.tree import predict_raw
from repro.data.tabular import adult_like, train_test_split


def _seed_predict(model, data) -> np.ndarray:
    """The per-call path as it stood at the seed: full conversion + generic
    traversal on every call."""
    ds = encode_dataset(data, model.spec)
    X = raw_matrix(ds, model.features)
    return model._finalize(predict_raw(model.forest, X))


def _best_of(fns: list, reps: int) -> tuple[list[float], list]:
    """Best-of-reps per candidate, reps interleaved so background load
    perturbs every candidate equally (same protocol as train_bench)."""
    best = [np.inf] * len(fns)
    outs = [None] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            outs[i] = fn()
            best[i] = min(best[i], time.perf_counter() - t0)
    return best, outs


def _cpu_strategies(forest) -> list[str]:
    """The CPU traversal strategies to column in the report, in preference
    order: every one offered by the engine registry except the oracle."""
    from repro.core.engines import available_engines
    return [e for e in available_engines(forest)
            if e in ("bucketed", "leaf_path", "vectorized")]


def run(rows: int = 100_000, num_trees: int = 30, reps: int = 3,
        verbose: bool = True, include_interpret: bool = False,
        sklearn_trees: int = 300) -> dict:
    import jax

    from repro.core.engines import JIT_ENGINES, compile_predictor
    on_tpu = jax.default_backend() == "tpu"
    train, _ = train_test_split(adult_like(max(2000, min(rows, 4000))), 0.3, 1)
    serve = adult_like(rows, seed=7)
    serve.pop("income")  # serving requests carry features only (§5.1)

    out: dict = {
        "benchmark": "infer_bench",
        "host": {"platform": platform.platform(), "numpy": np.__version__,
                 "jax_backend": jax.default_backend()},
        "rows": rows,
        "num_trees": num_trees,
        "configs": {},
    }
    models = [
        ("gbt_adult", GradientBoostedTreesLearner(
            label="income", num_trees=num_trees).train(train)),
        ("rf_adult", RandomForestLearner(
            label="income", num_trees=max(10, num_trees // 3),
            max_depth=12).train(train)),
    ]
    for name, model in models:
        # the seed path needs every dataspec column present
        seed_batch = dict(serve)
        seed_batch["income"] = np.full(rows, "<=50K", object)

        engines = _cpu_strategies(model.forest) + (["pallas"] if on_tpu else [])
        if include_interpret and not on_tpu:
            engines.append("pallas")
        fns = [lambda m=model, b=seed_batch: _seed_predict(m, b)]
        compile_s = {}
        small = {k: v[:64] for k, v in serve.items()}
        for ename in engines:
            t0 = time.perf_counter()
            pred = compile_predictor(model, ename)
            if ename in JIT_ENGINES:
                # jit'd: the trace/XLA-compile happens on the first call at
                # the timed shape — that IS the one-time compile cost
                pred.predict(serve)
                compile_s[ename] = time.perf_counter() - t0
            else:
                # non-jit: compile cost is the specialization alone; warm
                # the code path untimed on a small slice
                compile_s[ename] = time.perf_counter() - t0
                pred.predict(small)
            fns.append(lambda p=pred: p.predict(serve))
        times, preds = _best_of(fns, reps)
        t_before = times[0]
        row = {"n_rows": rows,
               "us_example_before": round(t_before / rows * 1e6, 3),
               "after": {}}
        for k, ename in enumerate(engines, start=1):
            row["after"][ename] = {
                "us_example": round(times[k] / rows * 1e6, 3),
                "speedup": round(t_before / times[k], 3),
                "compile_s": round(compile_s[ename], 4),
                "allclose": bool(np.allclose(preds[k], preds[0], atol=1e-5)),
            }
        out["configs"][name] = row
        if verbose:
            for ename in engines:
                a = row["after"][ename]
                print(f"  {name:12s} n={rows:<7d} "
                      f"before={row['us_example_before']:8.2f} us/ex  "
                      f"{ename:10s}={a['us_example']:8.2f} us/ex  "
                      f"speedup={a['speedup']:5.2f}x  allclose={a['allclose']}",
                      flush=True)
    sk = _run_sklearn_import(rows=rows, reps=reps, verbose=verbose,
                             n_trees=sklearn_trees)
    if sk is not None:
        out["configs"]["sklearn_import"] = sk
    out["profile"] = _profile_section(models[0][1], serve, verbose)
    out["headline_speedup"] = max(
        a["speedup"] for a in out["configs"]["gbt_adult"]["after"].values())
    return out


def _profile_section(model, serve, verbose: bool) -> dict:
    """Phase breakdown of traced inference (DESIGN.md §13.6): compile vs
    dispatch time for the auto-selected engine, recorded in the BENCH
    trajectory alongside the headline ratios."""
    from repro.core.engines import compile_predictor
    from repro.obs import trace
    from repro.obs.export import profile_dict

    with trace.capture() as tracer:
        pred = compile_predictor(model)
        for _ in range(3):
            pred.predict(serve)
    prof = profile_dict(tracer)
    prof["engine"] = pred.name
    if verbose:
        top = sorted(prof["phases"].items(),
                     key=lambda kv: -kv[1]["total_s"])[:4]
        print("  profile (traced gbt_adult): " + ", ".join(
            f"{n} {d['total_s'] * 1e3:.0f}ms x{d['count']}"
            for n, d in top), flush=True)
    return prof


def _run_sklearn_import(rows: int, reps: int, verbose: bool,
                        n_trees: int = 300) -> dict | None:
    """Imported n_trees-tree sklearn RF through the compiled predictor vs
    sklearn's own predict_proba (both in-process, same rows), one column
    per CPU traversal strategy."""
    try:
        from sklearn.ensemble import RandomForestClassifier
    except ImportError:
        if verbose:
            print("  sklearn_import skipped (scikit-learn not installed)")
        return None
    from repro.core.engines import JIT_ENGINES, compile_predictor
    from repro.interop import from_sklearn

    rng = np.random.default_rng(11)
    F = 10
    X = rng.normal(size=(4000, F)).astype(np.float32)
    y = (X[:, 0] + np.square(X[:, 1]) - X[:, 2] > 0.3).astype(int)
    est = RandomForestClassifier(n_estimators=n_trees, max_depth=12,
                                 random_state=0).fit(X, y)
    model = from_sklearn(est)
    X_serve = rng.normal(size=(rows, F)).astype(np.float32)
    batch = {f"f{i}": X_serve[:, i] for i in range(F)}
    strategies = _cpu_strategies(model.forest)
    fns = [lambda: est.predict_proba(X_serve)]
    compile_s = {}
    for ename in strategies:
        t0 = time.perf_counter()
        pred = compile_predictor(model, ename)
        if ename in JIT_ENGINES:
            pred.predict(batch)                  # trace at the timed shape
        else:
            pred.predict({k: v[:64] for k, v in batch.items()})
        compile_s[ename] = time.perf_counter() - t0
        fns.append(lambda p=pred: p.predict(batch))
    est.predict_proba(X_serve[:64])              # sklearn warm, untimed
    times, outs = _best_of(fns, reps)
    row = {
        "n_rows": rows,
        "n_trees": len(est.estimators_),
        "total_nodes": int(model.forest.n_nodes.sum()),
        "max_depth": int(model.forest.depth),
        "us_example_sklearn": round(times[0] / rows * 1e6, 3),
        "strategies": {},
    }
    for k, ename in enumerate(strategies, start=1):
        row["strategies"][ename] = {
            "us_example": round(times[k] / rows * 1e6, 3),
            "speedup_vs_sklearn": round(times[0] / times[k], 3),
            "compile_s": round(compile_s[ename], 4),
            "allclose": bool(np.allclose(outs[k], outs[0], atol=1e-5)),
        }
    best = max(row["strategies"], key=lambda e:
               row["strategies"][e]["speedup_vs_sklearn"])
    row["best_strategy"] = best
    row["us_example_compiled"] = row["strategies"][best]["us_example"]
    row["speedup_vs_sklearn"] = row["strategies"][best]["speedup_vs_sklearn"]
    row["allclose"] = row["strategies"][best]["allclose"]
    if verbose:
        for ename, a in row["strategies"].items():
            print(f"  sklearn_import n={rows:<7d} "
                  f"sklearn={row['us_example_sklearn']:8.2f} us/ex  "
                  f"{ename:10s}={a['us_example']:8.2f} us/ex  "
                  f"ratio={a['speedup_vs_sklearn']:5.2f}x  "
                  f"allclose={a['allclose']}", flush=True)
    return row


def run_smoke() -> dict:
    """Tiny end-to-end pass over every strategy on real (adult-like +
    sklearn-import) data — exercised inside tier-1 (tests/
    test_traversal_strategies.py) so the benchmark harness itself cannot
    rot between full runs."""
    return run(rows=1500, num_trees=4, reps=1, verbose=False,
               sklearn_trees=25)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=100_000)
    ap.add_argument("--trees", type=int, default=30)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="smoke sizes (1.5k rows, tiny forests)")
    ap.add_argument("--out", default="BENCH_infer.json")
    args = ap.parse_args()
    if args.quick:
        res = run_smoke()
    else:
        res = run(rows=args.rows, num_trees=args.trees, reps=args.reps)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    sk = res["configs"].get("sklearn_import")
    if sk:
        print(f"sklearn_import best={sk['best_strategy']} "
              f"ratio={sk['speedup_vs_sklearn']:.2f}x")
    print(f"headline (gbt_adult, best compiled engine vs seed per-call "
          f"path): {res['headline_speedup']:.2f}x -> {args.out}")


if __name__ == "__main__":
    main()
