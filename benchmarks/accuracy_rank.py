"""Paper §5 accuracy benchmark: learners x datasets, k-fold CV with
fold splits SHARED across learners, mean-rank aggregation (Fig. 6) and
pairwise wins/losses (Tab. 3).

Scaled-down stand-in: synthetic suite (see data/tabular.py) instead of the 70
OpenML sets (offline), fewer trees/folds/trials — protocol identical; scale
knobs at the top.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    CartLearner,
    GradientBoostedTreesLearner,
    HyperParameterTuner,
    LinearLearner,
    RandomForestLearner,
)
from repro.core.dataspec import dataset_from_raw
from repro.core.metalearners import kfold_indices
from repro.data.tabular import SUITE, make_dataset

FOLDS = 3
NUM_TREES = 25
TUNER_TRIALS = 4


def learners():
    gbt = lambda **kw: GradientBoostedTreesLearner(num_trees=NUM_TREES, **kw)
    rf = lambda **kw: RandomForestLearner(num_trees=NUM_TREES, **kw)
    return {
        "YDF GBT (default hp)": lambda: gbt(label="label"),
        "YDF GBT (benchmark hp)": lambda: gbt(label="label",
                                              template="benchmark_rank1"),
        "YDF RF (default hp)": lambda: rf(label="label"),
        "YDF RF (benchmark hp)": lambda: rf(label="label",
                                            template="benchmark_rank1"),
        "YDF CART": lambda: CartLearner(label="label"),
        "Linear (default hp)": lambda: LinearLearner(label="label"),
        "YDF Autotuned (opt acc)": lambda: HyperParameterTuner(
            gbt, {"max_depth": [3, 6, 8], "shrinkage": [0.05, 0.1, 0.3]},
            label="label", n_trials=TUNER_TRIALS, metric="accuracy"),
    }


def run(verbose: bool = True) -> dict:
    accs: dict[str, dict[str, list[float]]] = {}
    times: dict[str, float] = {}
    datasets = [s for s in SUITE if s.n_classes > 0][:5]
    for spec in datasets:
        data = make_dataset(spec)
        ds = dataset_from_raw(data)
        folds = kfold_indices(ds.n_rows, FOLDS, seed=spec.seed)  # shared folds
        for lname, make in learners().items():
            fold_accs = []
            t0 = time.perf_counter()
            for tr, va in folds:
                model = make().train(ds.subset(tr))
                fold_accs.append(model.evaluate(ds.subset(va))["accuracy"])
            times[lname] = times.get(lname, 0.0) + time.perf_counter() - t0
            accs.setdefault(spec.name, {})[lname] = fold_accs
            if verbose:
                print(f"  {spec.name:14s} {lname:26s} "
                      f"acc={np.mean(fold_accs):.4f}", flush=True)

    # mean rank over datasets (Fig. 6)
    names = list(learners())
    ranks = {n: [] for n in names}
    for dname, table in accs.items():
        means = np.array([np.mean(table[n]) for n in names])
        order = (-means).argsort().argsort() + 1  # rank 1 = best
        for n, r in zip(names, order):
            ranks[n].append(int(r))
    mean_rank = {n: float(np.mean(r)) for n, r in ranks.items()}

    # pairwise wins/losses over (dataset, fold) cells (Tab. 3)
    wins = {(a, b): 0.0 for a in names for b in names if a != b}
    for table in accs.values():
        for a in names:
            for b in names:
                if a == b:
                    continue
                for fa, fb in zip(table[a], table[b]):
                    wins[(a, b)] += 1.0 if fa > fb else (0.5 if fa == fb else 0.0)
    return {"accs": accs, "mean_rank": mean_rank, "wins": wins,
            "train_time_s": times}


def main():
    out = run()
    print("\n== mean rank (lower is better; Fig. 6 analogue) ==")
    for n, r in sorted(out["mean_rank"].items(), key=lambda kv: kv[1]):
        print(f"  {r:5.2f}  {n}   [train {out['train_time_s'][n]:.1f}s]")
    print("\n== pairwise wins (row beats column; Tab. 3 analogue) ==")
    names = list(out["mean_rank"])
    for a in names:
        row = " ".join(f"{out['wins'][(a, b)]:5.1f}" if a != b else "    -"
                       for b in names)
        print(f"  {a:26s} {row}")


if __name__ == "__main__":
    main()
