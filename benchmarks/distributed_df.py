"""Distributed DF training benchmark (paper §3.9 / Guillame-Bert & Teytaud):
per-level communication volume vs N (the key claim: candidate traffic is
independent of the number of examples; partitions are bit-packed), using the
single-process simulation backend."""
from __future__ import annotations

import numpy as np

from repro.core.distributed import DistGBTConfig, SimulatedCluster


def run(verbose: bool = True) -> dict:
    cfg = DistGBTConfig(max_depth=4, n_bins=64)
    rows = {}
    for N in (512, 2048, 8192):
        rng = np.random.default_rng(0)
        codes = rng.integers(0, 64, (N, 16)).astype(np.uint8)
        stats = np.stack([rng.normal(size=N), np.ones(N), np.ones(N)], 1)
        sim = SimulatedCluster(codes, 8, cfg, seed=0)
        sim.grow_tree(stats)
        bitmap = N // 8 * cfg.max_depth
        candidates = sim.traffic_bytes - bitmap
        rows[N] = {"total_bytes": sim.traffic_bytes,
                   "candidate_bytes": candidates,
                   "bitmap_bytes": bitmap,
                   "float_mask_bytes": N * 4 * cfg.max_depth}
        if verbose:
            r = rows[N]
            print(f"  N={N:6d}: candidates={r['candidate_bytes']:7d}B "
                  f"(N-independent)  bitmap={r['bitmap_bytes']:7d}B "
                  f"(vs {r['float_mask_bytes']}B unpacked = "
                  f"{r['float_mask_bytes'] / r['bitmap_bytes']:.0f}x)", flush=True)
    return rows


def main():
    out = run(verbose=False)
    print("n_examples,candidate_bytes,bitmap_bytes,float_mask_bytes")
    for n, r in out.items():
        print(f"{n},{r['candidate_bytes']},{r['bitmap_bytes']},{r['float_mask_bytes']}")


if __name__ == "__main__":
    main()
