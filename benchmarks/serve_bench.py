"""Sustained-throughput serving benchmark (DESIGN.md §9.6): p50/p99 request
latency vs offered QPS through the fault-tolerant front-end, clean and
fault-injected. Writes BENCH_serve.json.

Protocol (the measuring stick is "A Comparison of Decision Forest Inference
Platforms from A Database Perspective": report latency percentiles under
offered load, not just best-case throughput):

* OPEN-LOOP arrival: requests arrive on a fixed schedule (``i / qps``),
  whether or not the server keeps up — so overload shows up as queue depth,
  sheds and deadline misses instead of silently slowing the generator.
* Each request is a small row batch with a deadline; the server micro-
  batches pending requests into padded bucket dispatches on a fixed flush
  interval (and on max_batch pressure).
* The ``faults`` mode replays a SEEDED FaultPlan on the primary engine
  (transient errors, poisoned outputs, latency spikes): the same schedule
  every run. The server must degrade loudly — shed/timeout/fail counters —
  while every ACCEPTED-and-completed request stays bit-identical to a
  direct clean-bundle call (checked on a sample every run).

Usage: python benchmarks/serve_bench.py [--duration S] [--qps q1 q2 ...]
       [--out PATH] [--quick]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

from repro.core import GradientBoostedTreesLearner
from repro.data.tabular import adult_like, train_test_split
from repro.serving.faults import FaultPlan
from repro.serving.server import ForestServer, RequestShed, RetryPolicy

DEFAULT_QPS = (250, 1000, 4000)
FAULT_PLAN = dict(transient_rate=0.03, poison_rate=0.01,
                  latency_rate=0.02, latency_s=0.004)


def _drive(model, requests, clean_ref, qps: float, duration_s: float,
           deadline_s: float, fault_seed: int | None,
           flush_interval_s: float = 0.002, equiv_sample: int = 50) -> dict:
    """One sustained-load run at ``qps``; returns the metrics row."""
    srv = ForestServer(model, buckets=(32, 128, 512),
                       default_deadline_s=deadline_s,
                       max_batch=512, max_results=1 << 20,
                       retry=RetryPolicy(max_attempts=3, base_s=5e-4, seed=3),
                       failure_threshold=4, cooldown_s=0.05, warmup=True)
    if fault_seed is not None:
        srv.inject_faults(FaultPlan(seed=fault_seed, **FAULT_PLAN))
    n_req = max(1, int(qps * duration_s))
    tickets: dict[int, int] = {}        # ticket -> request index
    equiv_checked = equiv_ok = 0
    t0 = time.perf_counter()
    last_pump = t0

    def _claim(resolved):
        nonlocal equiv_checked, equiv_ok
        for t in resolved:
            i = tickets.pop(t, None)
            if i is None:
                continue
            try:
                out = srv.result(t)
            except Exception:
                continue                 # typed shed/timeout/fail: counted
            if equiv_checked < equiv_sample:
                equiv_checked += 1
                equiv_ok += int(np.array_equal(out, clean_ref[i]))

    for i in range(n_req):
        t_arr = t0 + i / qps
        now = time.perf_counter()
        if now < t_arr:
            time.sleep(t_arr - now)
        try:
            t = srv.submit(requests[i % len(requests)], pump=False)
            tickets[t] = i % len(requests)
        except RequestShed:
            pass
        now = time.perf_counter()
        if now - last_pump >= flush_interval_s \
                or srv._state(None).pending_rows() >= srv.max_batch:
            _claim(srv.pump())
            last_pump = time.perf_counter()
    _claim(srv.pump())
    wall = time.perf_counter() - t0
    m = srv.metrics.to_dict()
    return {
        "offered_qps": qps,
        "achieved_qps": round(m["submitted"] / wall, 1),
        "completed_qps": round(m["completed"] / wall, 1),
        "wall_s": round(wall, 3),
        "p50_ms": m["latency"]["p50_ms"],
        "p99_ms": m["latency"]["p99_ms"],
        "counters": {k: m[k] for k in (
            "submitted", "accepted", "shed", "timed_out", "completed",
            "failed", "retries", "fallback_dispatches", "poisoned_rejected",
            "circuit_opens", "circuit_closes", "dispatches",
            "rows_dispatched", "rows_padded")},
        "engine_dispatches": m["engine_dispatches"],
        "padding_by_bucket": m["padding_by_bucket"],
        # §13.4 survivorship fix: headline p50/p99 covers COMPLETED
        # requests only; shed/timed-out sojourn times are separate series
        "latency_by_outcome": m["latency_by_outcome"],
        "equiv_checked": equiv_checked,
        "equiv_ok": equiv_ok,
    }


def run(qps_levels=DEFAULT_QPS, duration_s: float = 2.0,
        rows_per_request: int = 4, num_trees: int = 20,
        deadline_ms: float = 50.0, fault_seed: int = 7,
        verbose: bool = True, out_path: str | None = None) -> dict:
    import jax
    train, test = train_test_split(adult_like(3000), 0.3, 1)
    model = GradientBoostedTreesLearner(
        label="income", num_trees=num_trees).train(train)
    feats = {k: v for k, v in test.items() if k != "income"}
    n_test = len(next(iter(feats.values())))
    requests = [{k: v[i:i + rows_per_request] for k, v in feats.items()}
                for i in range(0, n_test - rows_per_request,
                               rows_per_request)]
    # the clean reference: direct bundle calls, no server, no faults
    clean_ref = [model.predict(r) for r in requests]

    res: dict = {
        "benchmark": "serve_bench",
        "host": {"platform": platform.platform(), "numpy": np.__version__,
                 "jax_backend": jax.default_backend()},
        "num_trees": num_trees,
        "rows_per_request": rows_per_request,
        "duration_s": duration_s,
        "deadline_ms": deadline_ms,
        "fault_plan": {"seed": fault_seed, **FAULT_PLAN},
        "levels": {},
    }
    for qps in qps_levels:
        row = {}
        for mode, seed in (("clean", None), ("faults", fault_seed)):
            r = _drive(model, requests, clean_ref, qps, duration_s,
                       deadline_ms / 1e3, seed)
            row[mode] = r
            if verbose:
                c = r["counters"]
                print(f"  {qps:>6.0f} qps [{mode:6s}] p50={r['p50_ms']} ms "
                      f"p99={r['p99_ms']} ms  completed={c['completed']} "
                      f"shed={c['shed']} timed_out={c['timed_out']} "
                      f"failed={c['failed']} retries={c['retries']} "
                      f"fallback={c['fallback_dispatches']} "
                      f"equiv={r['equiv_ok']}/{r['equiv_checked']}",
                      flush=True)
                lo = r["latency_by_outcome"]
                if lo["timed_out"]["n"] or lo["shed"]["n"]:
                    print("           note: headline p50/p99 covers "
                          "completed requests only (survivorship); "
                          f"timed_out p99={lo['timed_out']['p99_ms']} ms "
                          f"(n={lo['timed_out']['n']}), shed est "
                          f"p50={lo['shed']['p50_ms']} ms "
                          f"(n={lo['shed']['n']})", flush=True)
            assert r["equiv_ok"] == r["equiv_checked"], \
                "accepted requests must be bit-identical to clean predictions"
        res["levels"][str(int(qps))] = row
    if out_path:
        with open(out_path, "w") as f:
            json.dump(res, f, indent=1)
        if verbose:
            print(f"wrote {out_path}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--qps", type=float, nargs="*", default=list(DEFAULT_QPS))
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--trees", type=int, default=20)
    ap.add_argument("--quick", action="store_true",
                    help="short sweep for benchmarks/run.py")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    duration = 0.5 if args.quick else args.duration
    run(qps_levels=tuple(args.qps), duration_s=duration,
        num_trees=args.trees, out_path=args.out)


if __name__ == "__main__":
    main()
