"""Paper Tab. 2/6/7: training and inference wall-time per learner (seconds),
averaged over the synthetic suite. CSV output: name,train_s,infer_s."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    GradientBoostedTreesLearner,
    LinearLearner,
    RandomForestLearner,
)
from repro.data.tabular import SUITE, make_dataset, train_test_split

NUM_TREES = 30


def learners():
    return {
        "YDF GBT (default hp)": lambda: GradientBoostedTreesLearner(
            label="label", num_trees=NUM_TREES),
        "YDF GBT (benchmark hp)": lambda: GradientBoostedTreesLearner(
            label="label", num_trees=NUM_TREES, template="benchmark_rank1"),
        "YDF RF (default hp)": lambda: RandomForestLearner(
            label="label", num_trees=NUM_TREES, compute_oob=False),
        "YDF RF (benchmark hp)": lambda: RandomForestLearner(
            label="label", num_trees=NUM_TREES, compute_oob=False,
            template="benchmark_rank1"),
        "Linear (default hp)": lambda: LinearLearner(label="label"),
    }


def run(verbose: bool = True) -> dict:
    rows = {}
    datasets = [s for s in SUITE if s.n_classes > 0][:4]
    for lname, make in learners().items():
        t_train = t_inf = 0.0
        for spec in datasets:
            train, test = train_test_split(make_dataset(spec), 0.3, spec.seed)
            t0 = time.perf_counter()
            model = make().train(train)
            t_train += time.perf_counter() - t0
            model.predict(test)  # warm the engine
            t0 = time.perf_counter()
            model.predict(test)
            t_inf += time.perf_counter() - t0
        rows[lname] = (t_train / len(datasets), t_inf / len(datasets))
        if verbose:
            print(f"  {lname:26s} train={rows[lname][0]:7.2f}s "
                  f"infer={rows[lname][1] * 1e3:7.1f}ms", flush=True)
    return rows


def main():
    print("name,train_s,infer_s")
    for n, (tt, ti) in run(verbose=False).items():
        print(f"{n},{tt:.3f},{ti:.4f}")


if __name__ == "__main__":
    main()
