"""Ranking-gradient benchmark: the group-batched LambdaMART lambda pass vs
a per-group Python loop. Writes BENCH_rank.json (DESIGN.md §12).

"naive"   = one `_lambda_pass` call per group at the group's own (m_g, m_g)
pair-matrix size — the textbook implementation shape, dominated by Python
dispatch and tiny-kernel overhead.
"batched" = every group padded into ONE (groups, max_group, max_group)
stack and swept in a single vectorized pass (tasks/ranking.py) — the form
the GBT training loop actually runs each boosting iteration.

Both paths share the same kernel, so agreement is exact up to padding: the
bench asserts max |Δ| <= 1e-12 on gradients AND hessians (at equal padded
widths the two are bit-identical — pinned in tests/test_tasks.py).

The win is shape-dependent and reported per shape, not hidden: with
near-uniform group sizes (the common retrieval case — a fixed candidate
count per query) the batched pass wins by >5x; heavy size skew pads every
group to the largest and the O(max^2) waste can hand the round back to the
loop. The headline tracks the uniform shape the GBT ranking loop targets.

Usage: python -m benchmarks.rank_bench [--groups N] [--reps R] [--out PATH]
       [--quick]   (tiny smoke sizes; also exercised inside tier-1 tests)
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

from repro.tasks.ranking import group_layout, lambda_grad_batched, \
    lambda_grad_naive


def _make_groups(n_groups: int, lo: int, hi: int, seed: int):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(lo, hi + 1, n_groups)
    groups = np.repeat(np.arange(n_groups), sizes)
    n = len(groups)
    scores = rng.normal(size=n)
    rel = rng.integers(0, 5, n).astype(np.float64)
    return groups, scores, rel


def _best_of(fns: list, reps: int) -> tuple[list[float], list]:
    """Best-of-reps, reps interleaved so background load perturbs every
    candidate equally (same protocol as infer_bench)."""
    best = [np.inf] * len(fns)
    outs = [None] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            outs[i] = fn()
            best[i] = min(best[i], time.perf_counter() - t0)
    return best, outs


def run(n_groups: int = 1500, reps: int = 3, verbose: bool = True) -> dict:
    out: dict = {
        "benchmark": "rank_bench",
        "host": {"platform": platform.platform(), "numpy": np.__version__},
        "configs": {},
    }
    shapes = [
        ("uniform_small", n_groups, 8, 16),
        ("uniform_large", max(2, n_groups // 4), 32, 64),
        ("skewed", n_groups, 2, 48),
    ]
    for name, g, lo, hi in shapes:
        groups, scores, rel = _make_groups(g, lo, hi, seed=3)
        layout = group_layout(groups)
        k = 5
        fns = [
            lambda: lambda_grad_naive(scores, rel, layout, k=k),
            lambda: lambda_grad_batched(scores, rel, layout, k=k),
        ]
        times, (naive, batched) = _best_of(fns, reps)
        dg = float(np.abs(naive[0] - batched[0]).max())
        dh = float(np.abs(naive[1] - batched[1]).max())
        row = {
            "n_groups": layout.n_groups,
            "n_rows": layout.n_rows,
            "max_group": layout.max_size,
            "ms_naive": round(times[0] * 1e3, 3),
            "ms_batched": round(times[1] * 1e3, 3),
            "speedup": round(times[0] / times[1], 3),
            "max_abs_diff_grad": dg,
            "max_abs_diff_hess": dh,
            "agree_1e12": bool(dg <= 1e-12 and dh <= 1e-12),
        }
        out["configs"][name] = row
        if verbose:
            print(f"  {name:14s} groups={row['n_groups']:<6d} "
                  f"rows={row['n_rows']:<7d} naive={row['ms_naive']:8.2f} ms  "
                  f"batched={row['ms_batched']:8.2f} ms  "
                  f"speedup={row['speedup']:6.2f}x  "
                  f"agree<=1e-12={row['agree_1e12']}", flush=True)
    out["headline_speedup"] = max(
        c["speedup"] for c in out["configs"].values())
    out["all_agree_1e12"] = all(
        c["agree_1e12"] for c in out["configs"].values())
    return out


def run_smoke() -> dict:
    """Tiny pass over every shape — exercised inside tier-1 so the bench
    harness cannot rot between full runs."""
    return run(n_groups=40, reps=1, verbose=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", type=int, default=1500)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="smoke sizes (40 groups)")
    ap.add_argument("--out", default="BENCH_rank.json")
    args = ap.parse_args()
    res = run_smoke() if args.quick else run(n_groups=args.groups,
                                             reps=args.reps)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"headline (group-batched lambda pass vs per-group loop): "
          f"{res['headline_speedup']:.2f}x, agreement<=1e-12: "
          f"{res['all_agree_1e12']} -> {args.out}")


if __name__ == "__main__":
    main()
