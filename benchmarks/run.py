"""Benchmark entry point: one module per paper table/figure.

  python -m benchmarks.run [--skip accuracy speed ...]
  python -m benchmarks.run --profile   # traced phase breakdowns only:
                                       # re-runs the train/infer headline
                                       # configs with tracing on and writes
                                       # BENCH_profile.json (DESIGN.md §13)

  accuracy_rank   — Fig. 6 mean ranks + Tab. 3 pairwise wins
  speed           — Tab. 2 train/inference seconds
  engines_bench   — App. B.4 per-engine us/example
  infer_bench     — DESIGN.md §5 compiled serving stack vs seed per-call
                    path (BENCH_infer.json when run as a module)
  train_bench     — DESIGN.md §6 growth engines x histogram backends
                    (BENCH_train.json when run as a module; --quick here)
  analyze_bench   — DESIGN.md §8 permutation importance: compiled
                    batched-replica path vs naive per-feature loop
                    (BENCH_analyze.json when run as a module; quick here)
  rank_bench      — DESIGN.md §12 group-batched LambdaMART lambda pass vs
                    per-group loop (BENCH_rank.json when run as a module)
  serve_bench     — DESIGN.md §9 fault-tolerant front-end: p50/p99 latency
                    vs offered QPS, clean vs fault-injected
                    (BENCH_serve.json when run as a module; --quick here)
  distributed_df  — §3.9 traffic scaling
  roofline_report — assignment §Roofline/§Dry-run tables (from results/)
"""
from __future__ import annotations

import argparse
import time


def run_profile(out_path: str = "BENCH_profile.json") -> dict:
    """The --profile sub-mode: the train/infer headline configs re-run
    under the tracer, phase breakdowns written next to the BENCH files."""
    import json

    from benchmarks import infer_bench, train_bench
    from repro.core import GradientBoostedTreesLearner
    from repro.data.tabular import adult_like, train_test_split

    out = {"benchmark": "profile"}
    print("== traced training phases (DESIGN.md §13.6) ==", flush=True)
    out["train"] = train_bench._profile_section(9, verbose=True)
    print("== traced inference phases (DESIGN.md §13.6) ==", flush=True)
    train, _ = train_test_split(adult_like(2000), 0.3, 1)
    model = GradientBoostedTreesLearner(
        label="income", num_trees=10).train(train)
    serve = adult_like(20_000, seed=7)
    serve.pop("income")
    out["infer"] = infer_bench._profile_section(model, serve, verbose=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip", nargs="*", default=[])
    ap.add_argument("--profile", action="store_true",
                    help="only the traced phase-breakdown sub-mode "
                         "(writes BENCH_profile.json)")
    args = ap.parse_args()
    if args.profile:
        run_profile()
        return

    from benchmarks import accuracy_rank, analyze_bench, distributed_df, \
        engines_bench, infer_bench, rank_bench, serve_bench, speed, \
        train_bench

    t_all = time.time()
    if "speed" not in args.skip:
        print("== speed (paper Tab. 2) ==", flush=True)
        speed.run()
    if "train" not in args.skip:
        print("== training engines (DESIGN.md §6) ==", flush=True)
        res = train_bench.run(num_trees=9, scaled_rows=20_000, reps_cap=1,
                              include_device=False)
        print(f"  headline: GBT {res['headline_speedup']:.2f}x, "
              f"tree-parallel RF {res['rf_headline_speedup']:.2f}x vs the "
              "seed grower (full 100k-row run: python -m "
              "benchmarks.train_bench)")
    if "engines" not in args.skip:
        print("== engines (paper App. B.4) ==", flush=True)
        engines_bench.run()
    if "infer" not in args.skip:
        print("== inference serving stack (DESIGN.md §5/§10) ==", flush=True)
        res = infer_bench.run(rows=20_000, reps=2)
        line = (f"  headline: {res['headline_speedup']:.2f}x best compiled "
                "engine vs seed per-call path")
        sk = res["configs"].get("sklearn_import")
        if sk:
            line += (f"; {sk['speedup_vs_sklearn']:.2f}x vs sklearn "
                     f"({sk['best_strategy']})")
        print(line + " (full 100k-row run: python -m benchmarks.infer_bench)")
    if "serve" not in args.skip:
        print("== fault-tolerant serving front-end (DESIGN.md §9) ==",
              flush=True)
        res = serve_bench.run(qps_levels=(200, 800, 2400), duration_s=0.5,
                              num_trees=10)
        top = res["levels"]["2400"]
        print(f"  headline: p99 {top['clean']['p99_ms']} ms clean / "
              f"{top['faults']['p99_ms']} ms under injected faults at "
              "2400 offered qps (full sweep: python -m "
              "benchmarks.serve_bench)")
    if "analyze" not in args.skip:
        print("== model analysis (DESIGN.md §8) ==", flush=True)
        res = analyze_bench.run(rows=400, num_trees=30, max_depth=8,
                                repetitions=1, reps=1)
        print(f"  headline: {res['speedup']:.2f}x batched replicas vs naive "
              "loop at this small config (full 300-tree run: python -m "
              "benchmarks.analyze_bench)")
    if "rank" not in args.skip:
        print("== LambdaMART lambda pass (DESIGN.md §12) ==", flush=True)
        res = rank_bench.run(n_groups=400, reps=2)
        print(f"  headline: {res['headline_speedup']:.2f}x group-batched vs "
              f"per-group loop, agreement<=1e-12: {res['all_agree_1e12']} "
              "(full run: python -m benchmarks.rank_bench)")
    if "distributed" not in args.skip:
        print("== distributed DF traffic (paper §3.9) ==", flush=True)
        distributed_df.run()
    if "accuracy" not in args.skip:
        print("== accuracy ranks (paper Fig. 6 / Tab. 3) ==", flush=True)
        out = accuracy_rank.run(verbose=False)
        for n, r in sorted(out["mean_rank"].items(), key=lambda kv: kv[1]):
            print(f"  rank {r:5.2f}  {n}  [train {out['train_time_s'][n]:.1f}s]")
    if "roofline" not in args.skip:
        try:
            from benchmarks import roofline_report
            cells = roofline_report.load_cells()
            if cells:
                print(f"== roofline ({len(cells)} unrolled cells; full table in "
                      "EXPERIMENTS.md) ==", flush=True)
                worst = sorted(cells, key=lambda d: d["terms"]["roofline_fraction"])
                for d in worst[:3] + worst[-3:]:
                    t = d["terms"]
                    print(f"  {d['arch']:16s} {d['shape']:12s} dominant={t['dominant']:10s} "
                          f"roofline_frac={t['roofline_fraction']:.3f}")
        except Exception as e:
            print(f"  (roofline artifacts unavailable: {e})")
    print(f"\nall benchmarks done in {time.time() - t_all:.0f}s")


if __name__ == "__main__":
    main()
