"""Training-throughput benchmark: batched-frontier engine vs the
seed-equivalent oracle grower, per histogram backend. Writes BENCH_train.json
(the perf-trajectory baseline; paper Tab. 2 analogue for *training*).

"before" = growth_engine="oracle": the seed grower — per-node partition
loops, full-N histogram rebuilds, example-major (simple) histogram backend.
"after"  = growth_engine="batched": vectorized frontier routing, flattened
bincount leaf stats, parent-minus-sibling histogram subtraction, numpy (or
pallas, on TPU) histogram backend.

Every timed pair is also checked for bit-identical forests (the §2.3
contract: the optimized path must reproduce the simple module exactly).

Usage: python benchmarks/train_bench.py [--rows N] [--trees T] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time

import numpy as np

from repro.core import GradientBoostedTreesLearner, RandomForestLearner
from repro.data.tabular import SUITE, make_dataset, train_test_split

FOREST_KEYS = ["feature", "threshold", "split_bin", "cat_mask", "left_child",
               "leaf_value", "n_nodes"]


def _forests_identical(a, b) -> bool:
    return all(np.array_equal(getattr(a, k), getattr(b, k))
               for k in FOREST_KEYS)


def _time_pair(fns: list, reps: int):
    """Best-of-reps for each candidate, reps interleaved across candidates so
    background load perturbs every candidate equally."""
    best = [np.inf] * len(fns)
    models = [None] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            models[i] = fn()
            best[i] = min(best[i], time.perf_counter() - t0)
    return best, models


def _configs(num_trees: int, scaled_rows: int):
    """speed.py-style learner configs on the synthetic suite + a scaled
    dataset where the asymptotics show (the suite datasets are paper-small)."""
    small = SUITE[2]                                     # synth_adult, 2k rows
    scaled = dataclasses.replace(small, n=scaled_rows)
    gbt = lambda **kw: GradientBoostedTreesLearner(
        label="label", num_trees=num_trees, **kw)
    gbt_bf = lambda **kw: GradientBoostedTreesLearner(
        label="label", num_trees=num_trees,
        growing_strategy="BEST_FIRST_GLOBAL", **kw)
    rf = lambda **kw: RandomForestLearner(
        label="label", num_trees=max(10, num_trees // 3), max_depth=12,
        compute_oob=False, **kw)
    return [
        ("gbt_default_small", gbt, small, 4),
        ("gbt_default_scaled", gbt, scaled, 3),
        ("gbt_best_first_scaled", gbt_bf, scaled, 3),
        ("rf_scaled", rf, scaled, 2),
    ]


def run(num_trees: int = 30, scaled_rows: int = 100_000,
        verbose: bool = True) -> dict:
    import jax
    backends = ["numpy"]
    if jax.default_backend() == "tpu":
        backends.append("pallas")
    out: dict = {
        "benchmark": "train_bench",
        "host": {"platform": platform.platform(), "numpy": np.__version__,
                 "jax_backend": jax.default_backend()},
        "num_trees": num_trees,
        "scaled_rows": scaled_rows,
        "configs": {},
    }
    for name, make, spec, reps in _configs(num_trees, scaled_rows):
        train, _ = train_test_split(make_dataset(spec), 0.3, spec.seed)
        fns = [lambda: make(growth_engine="oracle").train(train)]
        for backend in backends:
            fns.append(lambda backend=backend: make(
                growth_engine="batched",
                histogram_backend=backend).train(train))
        times, models = _time_pair(fns, reps)
        t_before, m_before = times[0], models[0]
        row = {"dataset": spec.name, "n_rows": spec.n,
               "train_s_before": round(t_before, 4), "after": {}}
        for k, backend in enumerate(backends, start=1):
            row["after"][backend] = {
                "train_s": round(times[k], 4),
                "speedup": round(t_before / times[k], 3),
                "bit_identical": _forests_identical(m_before.forest,
                                                    models[k].forest),
            }
        out["configs"][name] = row
        if verbose:
            a = row["after"]["numpy"]
            print(f"  {name:24s} n={spec.n:<7d} before={t_before:7.2f}s "
                  f"after={a['train_s']:7.2f}s speedup={a['speedup']:5.2f}x "
                  f"bit_identical={a['bit_identical']}", flush=True)
    out["headline_speedup"] = out["configs"]["gbt_default_scaled"][
        "after"]["numpy"]["speedup"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=100_000,
                    help="scaled dataset size")
    ap.add_argument("--trees", type=int, default=30)
    ap.add_argument("--out", default="BENCH_train.json")
    args = ap.parse_args()
    res = run(num_trees=args.trees, scaled_rows=args.rows)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"headline (gbt_default_scaled, numpy backend): "
          f"{res['headline_speedup']:.2f}x -> {args.out}")


if __name__ == "__main__":
    main()
