"""Training-throughput benchmark: growth engines x histogram backends.
Writes BENCH_train.json (the perf-trajectory baseline; paper Tab. 2 analogue
for *training*).

"before" = growth_engine="oracle": the seed grower — per-node partition
loops, full-N histogram rebuilds, example-major (simple) histogram backend,
trees grown one at a time.
"after" engines:
  * "numpy"  — growth_engine="batched" + numpy histogram backend. For Random
    Forests this includes tree-parallel lockstep blocks with keyed per-node
    feature sampling + gathered sqrt(F)-column histograms (DESIGN.md §6.3).
  * "pallas" — batched + the one-hot-MXU histogram kernel (TPU hosts only;
    resolve_backend refuses interpret mode on the hot path).
  * "device" — growth_engine="device": the device-resident jitted level loop
    (DESIGN.md §6). On CPU hosts XLA's scatter makes it the portability /
    correctness path rather than the fast one — timed on the small configs
    (with compile time split out) so the number is recorded honestly without
    dominating the benchmark wall-clock.

Parity columns: "bit_identical" where the engines promise it (oracle vs
batched at equal seeds — including the tree-parallel RF config, where
lockstep is execution-only), "struct_identical"/"pred_close" for the device
engine (f32 gain ties may regrow an equally-good subtree).

Usage: python benchmarks/train_bench.py [--rows N] [--trees T] [--quick]
       [--no-device] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time

import numpy as np

from repro.core import GradientBoostedTreesLearner, RandomForestLearner
from repro.data.tabular import SUITE, make_dataset, train_test_split

FOREST_KEYS = ["feature", "threshold", "split_bin", "cat_mask", "left_child",
               "leaf_value", "n_nodes"]
STRUCT_KEYS = ["feature", "split_bin", "cat_mask", "left_child", "n_nodes"]


def _forests_identical(a, b, keys=FOREST_KEYS) -> bool:
    return all(np.array_equal(getattr(a, k), getattr(b, k)) for k in keys)


def _time_pair(fns: list, reps: int):
    """Best-of-reps for each candidate, reps interleaved across candidates so
    background load perturbs every candidate equally."""
    best = [np.inf] * len(fns)
    models = [None] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            models[i] = fn()
            best[i] = min(best[i], time.perf_counter() - t0)
    return best, models


def _configs(num_trees: int, scaled_rows: int):
    """speed.py-style learner configs on the synthetic suite + a scaled
    dataset where the asymptotics show (the suite datasets are paper-small)."""
    small = SUITE[2]                                     # synth_adult, 2k rows
    scaled = dataclasses.replace(small, n=scaled_rows)
    gbt = lambda **kw: GradientBoostedTreesLearner(
        label="label", num_trees=num_trees, **kw)
    gbt_bf = lambda **kw: GradientBoostedTreesLearner(
        label="label", num_trees=num_trees,
        growing_strategy="BEST_FIRST_GLOBAL", **kw)
    rf = lambda **kw: RandomForestLearner(
        label="label", num_trees=max(10, num_trees // 3), max_depth=12,
        compute_oob=False, **kw)
    rf_par = lambda **kw: RandomForestLearner(
        label="label", num_trees=num_trees, max_depth=12,
        compute_oob=False, **kw)                         # tree_parallelism=8
    return [
        ("gbt_default_small", gbt, small, 4),
        ("gbt_default_scaled", gbt, scaled, 4),
        ("gbt_best_first_scaled", gbt_bf, scaled, 3),
        ("rf_scaled", rf, scaled, 3),
        # the tree-parallel RF headline: a full-size forest where the
        # lockstep blocks + gathered sqrt(F) histograms amortize data prep
        ("rf_parallel_scaled", rf_par, scaled, 3),
    ]


def _device_configs(num_trees: int):
    """Device-engine measurements on suite-sized data. Cold run = compile +
    train; warm run reuses the jit cache (the steady-state number: one
    compiled program per frontier-width bucket, shared across trees)."""
    small = SUITE[2]
    gbt = lambda **kw: GradientBoostedTreesLearner(
        label="label", num_trees=num_trees, **kw)
    rf = lambda **kw: RandomForestLearner(
        label="label", num_trees=max(8, num_trees // 3), max_depth=8,
        compute_oob=False, **kw)
    return [("gbt_device_small", gbt, small),
            ("rf_device_small", rf, small)]


def run(num_trees: int = 30, scaled_rows: int = 100_000, reps_cap: int = 99,
        include_device: bool = True, verbose: bool = True) -> dict:
    import jax
    jb = jax.default_backend()
    backends = ["numpy"] + (["pallas"] if jb == "tpu" else [])
    out: dict = {
        "benchmark": "train_bench",
        "host": {"platform": platform.platform(), "numpy": np.__version__,
                 "jax_backend": jb},
        "num_trees": num_trees,
        "scaled_rows": scaled_rows,
        "configs": {},
    }
    for name, make, spec, reps in _configs(num_trees, scaled_rows):
        reps = min(reps, reps_cap)
        train, _ = train_test_split(make_dataset(spec), 0.3, spec.seed)
        fns = [lambda: make(growth_engine="oracle").train(train)]
        for backend in backends:
            fns.append(lambda backend=backend: make(
                growth_engine="batched",
                histogram_backend=backend).train(train))
        times, models = _time_pair(fns, reps)
        t_before, m_before = times[0], models[0]
        row = {"dataset": spec.name, "n_rows": spec.n, "jax_backend": jb,
               "train_s_before": round(t_before, 4), "after": {}}
        for k, backend in enumerate(backends, start=1):
            row["after"][backend] = {
                "train_s": round(times[k], 4),
                "speedup": round(t_before / times[k], 3),
                "bit_identical": _forests_identical(m_before.forest,
                                                    models[k].forest),
            }
        out["configs"][name] = row
        if verbose:
            a = row["after"]["numpy"]
            print(f"  {name:24s} n={spec.n:<7d} before={t_before:7.2f}s "
                  f"after={a['train_s']:7.2f}s speedup={a['speedup']:5.2f}x "
                  f"bit_identical={a['bit_identical']}", flush=True)

    if include_device:
        for name, make, spec in _device_configs(num_trees):
            train, _ = train_test_split(make_dataset(spec), 0.3, spec.seed)
            t0 = time.perf_counter()
            m_cold = make(growth_engine="device").train(train)
            cold = time.perf_counter() - t0
            t0 = time.perf_counter()
            m_dev = make(growth_engine="device").train(train)
            warm = time.perf_counter() - t0
            t0 = time.perf_counter()
            m_ref = make(growth_engine="batched").train(train)
            host = time.perf_counter() - t0
            pa = np.abs(m_ref.predict(train) - m_dev.predict(train))
            agree = min(float((getattr(m_ref.forest, k)
                               == getattr(m_dev.forest, k)).mean())
                        for k in STRUCT_KEYS)
            out["configs"][name] = {
                "dataset": spec.name, "n_rows": spec.n, "jax_backend": jb,
                "engine": m_dev.training_logs["growth_engine"],
                "train_s_cold": round(cold, 4),
                "train_s_warm": round(warm, 4),
                "compile_s": round(cold - warm, 4),
                "train_s_batched_numpy": round(host, 4),
                # f32 gain ties can regrow an equally-good subtree, so the
                # honest metric is node-level agreement + prediction delta
                "struct_identical": _forests_identical(
                    m_ref.forest, m_dev.forest, STRUCT_KEYS),
                "struct_agreement": round(agree, 5),
                "pred_mean_abs_diff": float(pa.mean()),
            }
            if verbose:
                r = out["configs"][name]
                print(f"  {name:24s} n={spec.n:<7d} warm={warm:7.2f}s "
                      f"compile={r['compile_s']:6.2f}s "
                      f"numpy={host:6.2f}s struct_identical="
                      f"{r['struct_identical']}", flush=True)

    out["checkpoint_overhead"] = _checkpoint_overhead(
        num_trees, reps_cap, verbose)

    out["profile"] = _profile_section(num_trees, verbose)

    out["headline_speedup"] = out["configs"]["gbt_default_scaled"][
        "after"]["numpy"]["speedup"]
    out["rf_headline_speedup"] = out["configs"]["rf_parallel_scaled"][
        "after"]["numpy"]["speedup"]
    return out


def _profile_section(num_trees: int, verbose: bool) -> dict:
    """Phase breakdown of one traced small-config GBT train (DESIGN.md
    §13.6): the BENCH trajectory records where training time GOES — per
    grower phase — not just the headline ratio."""
    from repro.obs import trace
    from repro.obs.export import profile_dict

    small = SUITE[2]
    train, _ = train_test_split(make_dataset(small), 0.3, small.seed)
    with trace.capture() as tracer:
        GradientBoostedTreesLearner(
            label="label", num_trees=num_trees).train(train)
    prof = profile_dict(tracer)
    prof["dataset"] = small.name
    prof["num_trees"] = num_trees
    if verbose:
        top = sorted(prof["phases"].items(),
                     key=lambda kv: -kv[1]["total_s"])[:5]
        print("  profile (traced small GBT): " + ", ".join(
            f"{n} {d['total_s'] * 1e3:.0f}ms x{d['count']}"
            for n, d in top), flush=True)
    return prof


def _checkpoint_overhead(num_trees: int, reps_cap: int, verbose: bool) -> dict:
    """Wall-clock cost of DESIGN.md §11 checkpointing at the default cadence
    (every 10 trees): interleaved best-of timing of train-without vs
    train-with-checkpoints. Acceptance: <= 5% overhead."""
    import shutil
    import tempfile

    from repro.train.checkpoint import CheckpointPolicy

    small = SUITE[2]
    train, _ = train_test_split(make_dataset(small), 0.3, small.seed)
    ckdir = tempfile.mkdtemp(prefix="bench-ck-")
    make = lambda: GradientBoostedTreesLearner(label="label",
                                               num_trees=num_trees)

    def with_ck():
        shutil.rmtree(ckdir, ignore_errors=True)
        return make().train(train, checkpoint=CheckpointPolicy(ckdir))

    try:
        (t_plain, t_ck), (m_plain, m_ck) = _time_pair(
            [lambda: make().train(train), with_ck], min(4, max(2, reps_cap)))
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)
    overhead = t_ck / t_plain - 1.0
    row = {
        "dataset": small.name, "num_trees": num_trees,
        "every_n_trees": 10,
        "train_s_plain": round(t_plain, 4),
        "train_s_checkpointed": round(t_ck, 4),
        "overhead_pct": round(100 * overhead, 2),
        "acceptance_max_pct": 5.0,
        "accepted": bool(overhead <= 0.05),
        "bit_identical": _forests_identical(m_plain.forest, m_ck.forest),
    }
    if verbose:
        print(f"  checkpoint_overhead      every=10 trees: "
              f"plain={t_plain:6.2f}s ck={t_ck:6.2f}s "
              f"overhead={row['overhead_pct']:+.2f}% "
              f"accepted={row['accepted']}", flush=True)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=None,
                    help="scaled dataset size (default 100000; 20000 under "
                    "--quick)")
    ap.add_argument("--trees", type=int, default=None,
                    help="trees per GBT config (default 30; 9 under --quick)")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run: 20k rows, 9 trees, single rep, "
                    "no device configs, no JSON overwrite by default "
                    "(explicit --rows/--trees are honored)")
    ap.add_argument("--no-device", action="store_true",
                    help="skip the device-engine configs")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default BENCH_train.json; "
                    "--quick defaults to not writing)")
    args = ap.parse_args()
    rows = args.rows if args.rows is not None else \
        (20_000 if args.quick else 100_000)
    trees = args.trees if args.trees is not None else \
        (9 if args.quick else 30)
    res = run(num_trees=trees, scaled_rows=rows,
              reps_cap=1 if args.quick else 99,
              include_device=not (args.no_device or args.quick))
    out_path = args.out or (None if args.quick else "BENCH_train.json")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(res, f, indent=2)
    print(f"headline GBT {res['headline_speedup']:.2f}x | "
          f"tree-parallel RF {res['rf_headline_speedup']:.2f}x"
          + (f" -> {out_path}" if out_path else ""))


if __name__ == "__main__":
    main()
