"""Paper App. B.4: inference-engine comparison (us/example per engine) on a
trained GBT and RF — the engine-compilation (§3.7) payoff, CPU edition.
(The pallas engine runs interpret-mode here: correctness path; TPU target.)"""
from __future__ import annotations

import time

import numpy as np

import repro.core.models as M
from repro.core import GradientBoostedTreesLearner, RandomForestLearner
from repro.core.engines import available_engines, compile_model
from repro.data.tabular import adult_like, train_test_split


def run(verbose: bool = True, include_interpret: bool = False) -> dict:
    train, test = train_test_split(adult_like(2000), 0.5, 1)
    out = {}
    for mname, learner in [
        ("GBT", GradientBoostedTreesLearner(label="income", num_trees=30)),
        ("RF", RandomForestLearner(label="income", num_trees=30, max_depth=12)),
    ]:
        model = learner.train(train)
        ds = M._as_vertical(test, model.spec)
        X = M.raw_matrix(ds, model.features)
        for ename in available_engines(model.forest):
            if ename == "pallas" and not include_interpret:
                continue  # interpret-mode timing is not meaningful
            eng = compile_model(model, ename)
            n = X.shape[0] if ename != "naive" else min(200, X.shape[0])
            eng.per_tree(X[:n])  # warm up at the timed shape (§5.1)
            t0 = time.perf_counter()
            eng.per_tree(X[:n])
            dt = time.perf_counter() - t0
            us = dt / n * 1e6
            out[f"{mname}/{ename}"] = us
            if verbose:
                print(f"  {mname:4s} {ename:12s} {us:10.2f} us/example", flush=True)
    return out


def main():
    print("model/engine,us_per_example")
    for k, v in run(verbose=False).items():
        print(f"{k},{v:.2f}")


if __name__ == "__main__":
    main()
