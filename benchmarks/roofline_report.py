"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
artifacts in results/dryrun/. One row per (arch x shape) single-pod cell from
the UNROLLED lowering (accurate HLO flops/bytes/collectives); the scanned
cells are the pass/fail + memory record."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(suffix: str = "__unrolled") -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(RESULTS, f"*{suffix}.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_row(d: dict) -> str:
    t = d["terms"]
    return (f"| {d['arch']} | {d['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {t['dominant']} | "
            f"{t['useful_ratio']:.3f} | {t['roofline_fraction']:.3f} |")


HEADER = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
          "dominant | useful ratio | roofline frac |\n"
          "|---|---|---|---|---|---|---|---|")


def render(suffix: str = "__unrolled") -> str:
    cells = load_cells(suffix)
    lines = [HEADER] + [fmt_row(d) for d in cells]
    return "\n".join(lines)


def render_dryrun_summary() -> str:
    """Scanned-cell summary: per-cell compile status + memory + collectives."""
    rows = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        if "unrolled" in p:
            continue
        d = json.load(open(p))
        mem = d.get("memory_analysis", {})
        args = mem.get("argument_size_in_bytes", 0)
        temp = mem.get("temp_size_in_bytes", 0)
        cc = d["collectives"]["count_by_kind"]
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | ok "
            f"({d['compile_s']:.0f}s) | {args / 2**30:.2f} | {temp / 2**30:.2f} | "
            f"{sum(cc.values())} ({'+'.join(f'{k}:{v}' for k, v in sorted(cc.items()))}) |")
    header = ("| arch | shape | mesh | compile | args GiB/dev | temps GiB/dev | "
              "collectives |\n|---|---|---|---|---|---|---|")
    return "\n".join([header] + rows)


def main():
    print("== §Roofline (single-pod, unrolled lowering) ==")
    print(render())
    print()
    print("== §Dry-run (scanned lowering, single+multi pod) ==")
    print(render_dryrun_summary())


if __name__ == "__main__":
    main()
